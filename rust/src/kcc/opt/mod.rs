//! Mid-level IR optimizer pipeline.
//!
//! The paper keeps data-parallel semantics in IR + metadata precisely so
//! "later generic compiler passes" can exploit them (§4). This module is
//! that generic layer for our kernel compiler: a classical scalar
//! optimizer that runs **before** region formation in
//! [`compile_workgroup`](super::passes::compile_workgroup), so every
//! engine (serial, gang, vecgang, fiber, ttasim, pjrt) and both cached
//! artifacts (`reg_fn` and `loop_fn`) profit from the same cleanup.
//! Because each engine dispatches the interpreter once per IR
//! instruction, every instruction deleted here is a direct,
//! `--stats`-visible speedup on all of them.
//!
//! One file per pass:
//!
//! * [`cfg_simplify`] — branch folding, jump threading through empty
//!   blocks, single-predecessor block merging, unreachable-block removal.
//! * [`fold`] — constant folding, evaluated with the **interpreter's own
//!   scalar kernels** (`exec::interp::bin_scalar` & friends) so folded
//!   results are bit-identical to runtime evaluation, including integer
//!   wrapping and f32 rounding. Division by a constant zero is never
//!   folded (the runtime error is preserved).
//! * [`algebraic`] — algebraic simplification and strength reduction on
//!   integer operations (`x*0`, `x+0`, `x*2^k → x<<k`, unsigned
//!   `/`/`%` by powers of two). Float identities are never rewritten.
//! * [`propagate`] — copy propagation through pointer-identity casts and
//!   constant-condition selects.
//! * [`cse`] — block-local common-subexpression elimination over pure
//!   instructions.
//! * [`loadfwd`] — private-memory store-to-load forwarding, redundant
//!   load elimination, and in-block dead-store elimination, aware of the
//!   cell-addressed private-memory model.
//! * [`dce`] — dead code elimination (the collector for all of the
//!   above: the other passes rewrite uses and leave dead defs behind).
//!
//! # Invariants every pass preserves
//!
//! * The block-local register invariant (`ir::verify` stays clean):
//!   substitution environments never introduce a register use in another
//!   block, and register-valued substitutions are flushed at barriers so
//!   no pass creates a register live range across a barrier
//!   (`kcc::barriers::split_at_barrier` would reject it later).
//! * Barriers and markers are never deleted, duplicated, or moved, and
//!   memory state tracked across a barrier is discarded — the reachable
//!   barrier count is exactly preserved.
//! * Bit-identical results: every folded value is computed by the same
//!   normalisation chain (`norm_int`/`norm_float`/`norm_val`) the
//!   engines use, so O0/O1/O2 produce byte-for-byte equal outputs.

pub mod algebraic;
pub mod cfg_simplify;
pub mod cse;
pub mod dce;
pub mod fold;
pub mod loadfwd;
pub mod propagate;

use std::collections::HashMap;

use crate::cl::error::Result;
use crate::ir::cfg::reachable;
use crate::ir::func::Function;
use crate::ir::inst::{Imm, Inst, Operand, Reg, Term};
use crate::ir::types::Scalar;
use crate::ir::verify::verify;
use crate::exec::value::{norm_float, norm_int, Val};

/// Optimisation level. Part of [`CompileOptions`](super::CompileOptions),
/// so it participates in every specialisation-cache key (in-memory and
/// on-disk): artifacts compiled at different levels never mix.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OptLevel {
    /// No optimisation: the frontend IR goes straight to region formation.
    O0,
    /// CFG cleanup + constant folding + copy propagation + DCE.
    O1,
    /// O1 plus CSE, load forwarding, and algebraic simplification.
    O2,
}

impl Default for OptLevel {
    fn default() -> Self {
        OptLevel::O2
    }
}

impl OptLevel {
    /// Level from the `POCLRS_OPT` environment variable (`0`/`1`/`2`),
    /// defaulting to O2. Invalid values warn once (`crate::envcfg`)
    /// instead of silently running at O2. Consulted by
    /// `CompileOptions::default()`, so the CLI `--opt` flag and the CI
    /// O0 matrix leg reach every device.
    pub fn from_env() -> OptLevel {
        crate::envcfg::parse_or_warn(
            "POCLRS_OPT",
            std::env::var("POCLRS_OPT").ok().as_deref(),
            "0, 1, or 2",
            "using O2",
            |s| s.parse::<u32>().ok().and_then(OptLevel::from_u32),
        )
        .unwrap_or_default()
    }

    /// Numeric level (for display).
    pub fn as_u32(self) -> u32 {
        match self {
            OptLevel::O0 => 0,
            OptLevel::O1 => 1,
            OptLevel::O2 => 2,
        }
    }

    /// Level from a number (CLI parsing). `None` for anything but 0/1/2.
    pub fn from_u32(n: u32) -> Option<OptLevel> {
        match n {
            0 => Some(OptLevel::O0),
            1 => Some(OptLevel::O1),
            2 => Some(OptLevel::O2),
            _ => None,
        }
    }
}

/// Per-pass optimizer statistics, embedded in
/// [`CompileStats`](super::CompileStats) and surfaced by
/// `poclrs run --stats`. Pass counters are cumulative over all fixpoint
/// iterations: rewrite counts for the rewriting passes, removal counts
/// for `dce`/`cfg_simplify`.
#[derive(Debug, Clone, Copy, Default)]
pub struct OptStats {
    /// Reachable instructions before the pipeline.
    pub insts_before: usize,
    /// Reachable instructions after the pipeline.
    pub insts_after: usize,
    /// Reachable blocks before the pipeline.
    pub blocks_before: usize,
    /// Reachable blocks after the pipeline.
    pub blocks_after: usize,
    /// Fixpoint iterations run.
    pub iterations: usize,
    /// CFG edits (branches folded + jumps threaded + blocks merged +
    /// unreachable blocks removed).
    pub cfg_simplified: usize,
    /// Operand rewrites from constant folding.
    pub folded: usize,
    /// Operand rewrites from algebraic simplification + strength
    /// reductions applied in place.
    pub algebraic: usize,
    /// Operand rewrites from copy propagation.
    pub propagated: usize,
    /// Operand rewrites from common-subexpression elimination.
    pub cse_hits: usize,
    /// Operand rewrites from load forwarding + dead stores removed.
    pub loads_forwarded: usize,
    /// Instructions removed by dead code elimination.
    pub dce_removed: usize,
}

impl OptStats {
    /// Total instructions removed by the pipeline.
    pub fn insts_removed(&self) -> usize {
        self.insts_before.saturating_sub(self.insts_after)
    }
}

/// Fixpoint cap: each iteration only shrinks the function, but the cap
/// bounds compile time on adversarial inputs.
const MAX_ITERATIONS: usize = 8;

/// Run `pass` under a tracer span named after it (compiler category);
/// one span per pass per fixpoint iteration.
fn traced(name: &'static str, pass: impl FnOnce() -> usize) -> usize {
    let _t = crate::trace::span(crate::trace::CAT_COMPILER, name);
    pass()
}

/// Run the optimizer pipeline on a single-work-item kernel function at
/// `level`. Returns the per-pass statistics. The function is verified
/// after the pipeline (and after every iteration in debug builds).
pub fn run(f: &mut Function, level: OptLevel) -> Result<OptStats> {
    let _opt_span = crate::trace::span(crate::trace::CAT_COMPILER, "optimize");
    let insts_before = f.inst_count();
    let blocks_before = reachable(f).len();
    let mut s = OptStats {
        insts_before,
        insts_after: insts_before,
        blocks_before,
        blocks_after: blocks_before,
        ..OptStats::default()
    };
    if level == OptLevel::O0 {
        return Ok(s);
    }
    for _ in 0..MAX_ITERATIONS {
        let mut changed = 0;
        let n = traced("opt.cfg_simplify", || cfg_simplify::run(f));
        s.cfg_simplified += n;
        changed += n;
        let n = traced("opt.fold", || fold::run(f));
        s.folded += n;
        changed += n;
        if level >= OptLevel::O2 {
            let n = traced("opt.algebraic", || algebraic::run(f));
            s.algebraic += n;
            changed += n;
        }
        let n = traced("opt.propagate", || propagate::run(f));
        s.propagated += n;
        changed += n;
        if level >= OptLevel::O2 {
            let n = traced("opt.cse", || cse::run(f));
            s.cse_hits += n;
            changed += n;
            let n = traced("opt.loadfwd", || loadfwd::run(f));
            s.loads_forwarded += n;
            changed += n;
        }
        let n = traced("opt.dce", || dce::run(f));
        s.dce_removed += n;
        changed += n;
        s.iterations += 1;
        #[cfg(debug_assertions)]
        verify(f)?;
        if changed == 0 {
            break;
        }
    }
    verify(f)?;
    s.insts_after = f.inst_count();
    s.blocks_after = reachable(f).len();
    Ok(s)
}

// ---------------------------------------------------------------------------
// Shared helpers for the passes.
// ---------------------------------------------------------------------------

/// An immediate's runtime value, exactly as `Machine::operand` computes it
/// (normalised by the immediate's own scalar type at read time).
pub(crate) fn imm_val(imm: &Imm) -> Val {
    match imm {
        Imm::Int(v, s) => Val::I(norm_int(*v, *s)),
        Imm::Float(v, s) => Val::F(norm_float(*v, *s)),
    }
}

/// Truthiness of an immediate under the interpreter's rules.
pub(crate) fn imm_truthy(imm: &Imm) -> bool {
    imm_val(imm).truthy()
}

/// Re-encode an interpreter value as an immediate of scalar type `s`.
/// The value must already be normalised to `s` (all interpreter kernels
/// normalise their outputs), so reading the immediate back through
/// `Machine::operand` — which normalises again, idempotently — yields the
/// identical runtime value. Pointers have no immediate form.
pub(crate) fn val_to_imm(v: Val, s: Scalar) -> Option<Imm> {
    match v {
        Val::I(i) => Some(Imm::Int(i, s)),
        Val::F(x) => Some(Imm::Float(x, s)),
        Val::Ptr { .. } => None,
    }
}

/// Result type of `inst` if the interpreter provably **normalises** its
/// output to that type — `Bin`/`Un`/`Math` normalise to their result
/// scalar, numeric `Cast`s to the target, `Wi` produces a `u64`, and
/// `Splat`/`VecBuild` normalise every element. Loads return raw cells and
/// `Select`/`VecExtract`/`VecInsert` pass values through unnormalised, so
/// they return `None`. Used by `algebraic` (identity rewrites) and
/// `loadfwd` (store-to-load forwarding), where substituting a register
/// for a normalised memory cell is only exact under this proof.
pub(crate) fn normalized_result(inst: &Inst) -> Option<crate::ir::types::Type> {
    use crate::ir::types::Type;
    match inst {
        Inst::Bin { .. } | Inst::Un { .. } | Inst::Math { .. } => Some(inst.result_ty()),
        Inst::Cast { to, .. } if to.elem_scalar().is_some() => Some(to.clone()),
        Inst::Wi { .. } => Some(Type::U64),
        Inst::Splat { ty, .. } | Inst::VecBuild { ty, .. } => Some(ty.clone()),
        _ => None,
    }
}

/// Block-local substitution environment: register → replacement operand.
///
/// Passes record discovered equivalences (`reg` is the constant `imm`,
/// `reg` copies `operand`) and rewrite subsequent operand uses through
/// the environment as they scan forward. The environment is per-block
/// (registers are block-local by IR invariant) and register-valued
/// entries are flushed at barriers so no rewrite creates a register live
/// range across a barrier.
#[derive(Default)]
pub(crate) struct Subst {
    map: HashMap<Reg, Operand>,
}

impl Subst {
    pub(crate) fn new() -> Subst {
        Subst::default()
    }

    /// Record that `r`'s value equals `op` (which must already be fully
    /// rewritten through this environment).
    pub(crate) fn set(&mut self, r: Reg, op: Operand) {
        self.map.insert(r, op);
    }

    /// Rewrite `inst`'s operands through the environment. Returns the
    /// number of operands rewritten.
    pub(crate) fn apply(&self, inst: &mut Inst) -> usize {
        let mut n = 0;
        for op in inst.operands_mut() {
            if let Operand::Reg(r) = op {
                if let Some(repl) = self.map.get(r) {
                    *op = *repl;
                    n += 1;
                }
            }
        }
        n
    }

    /// Rewrite a branch condition through the environment. Slot-valued
    /// replacements are skipped: the verifier forbids slot operands as
    /// branch conditions (and a pointer is never a real condition).
    pub(crate) fn apply_term(&self, term: &mut Term) -> usize {
        if let Term::Br { cond, .. } = term {
            if let Operand::Reg(r) = *cond {
                if let Some(repl) = self.map.get(&r) {
                    if !matches!(repl, Operand::Slot(_)) {
                        *cond = *repl;
                        return 1;
                    }
                }
            }
        }
        0
    }

    /// Drop register-valued substitutions (called at barriers: an
    /// immediate may be propagated across a barrier, a register must not).
    pub(crate) fn flush_regs(&mut self) {
        self.map.retain(|_, v| !matches!(v, Operand::Reg(_)));
    }
}
