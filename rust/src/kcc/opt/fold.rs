//! Constant folding.
//!
//! A pure scalar instruction whose operands are all immediates is
//! evaluated at compile time **with the interpreter's own kernels**
//! (`exec::interp::bin_scalar`/`eval_un`/`eval_cast`/`eval_math`), so the
//! folded constant is bit-identical to what any engine would compute —
//! including integer wrapping, unsigned comparison rules, and f32
//! rounding through `norm_float`. Uses of the folded register are
//! rewritten to the immediate; the defining instruction dies in `dce`.
//!
//! Instructions that can fail at runtime (integer division/remainder by
//! zero) are left alone when evaluation errors, preserving the runtime
//! error exactly.

use crate::exec::interp::{bin_scalar, eval_cast, eval_math, eval_un};
use crate::exec::value::VVal;
use crate::ir::func::Function;
use crate::ir::inst::{Imm, Inst, Operand};
use crate::ir::types::Scalar;

use super::{imm_val, val_to_imm, Subst};

/// Run constant folding over every block. Returns operand rewrites.
pub fn run(f: &mut Function) -> usize {
    let mut changed = 0;
    for bb in f.block_ids().collect::<Vec<_>>() {
        let block = f.block_mut(bb);
        let mut env = Subst::new();
        for (def, inst) in block.insts.iter_mut() {
            changed += env.apply(inst);
            if inst.is_barrier() {
                env.flush_regs();
                continue;
            }
            if let (Some(d), Some(imm)) = (def, try_fold(inst)) {
                env.set(*d, Operand::Imm(imm));
            }
        }
        changed += env.apply_term(&mut block.term);
    }
    changed
}

/// Immediate operand, if the operand is one.
fn as_imm(op: &Operand) -> Option<&Imm> {
    match op {
        Operand::Imm(i) => Some(i),
        _ => None,
    }
}

/// Evaluate a pure scalar instruction with all-immediate operands.
/// Returns `None` when the instruction is not foldable (non-scalar,
/// non-constant operands, pointer-valued result, or runtime error).
fn try_fold(inst: &Inst) -> Option<Imm> {
    match inst {
        Inst::Bin { op, ty, a, b } if ty.lanes() == 1 => {
            let s = ty.elem_scalar()?;
            let (ia, ib) = (as_imm(a)?, as_imm(b)?);
            let v = bin_scalar(*op, s, imm_val(ia), imm_val(ib)).ok()?;
            let out = if op.is_cmp() { Scalar::Bool } else { s };
            val_to_imm(v, out)
        }
        Inst::Un { op, ty, a } if ty.lanes() == 1 => {
            let s = ty.elem_scalar()?;
            let ia = as_imm(a)?;
            let v = eval_un(*op, ty, &VVal::S(imm_val(ia))).ok()?;
            val_to_imm(v.scalar(), s)
        }
        Inst::Cast { to, from, a } if to.lanes() == 1 => {
            let s = to.elem_scalar()?;
            let ia = as_imm(a)?;
            let v = eval_cast(&VVal::S(imm_val(ia)), from, to);
            val_to_imm(v.scalar(), s)
        }
        Inst::Math { func, ty, args } if ty.lanes() == 1 => {
            let s = ty.elem_scalar()?;
            let mut vals = Vec::with_capacity(args.len());
            for a in args {
                vals.push(VVal::S(imm_val(as_imm(a)?)));
            }
            match eval_math(*func, ty, &vals).ok()? {
                VVal::S(v) => val_to_imm(v, s),
                VVal::V(_) => None,
            }
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::inst::{BinOp, Term, UnOp};
    use crate::ir::types::Type;
    use crate::ir::verify::verify;

    #[test]
    fn folds_int_arith_with_wrapping() {
        let mut f = Function::new("k");
        let e = f.entry;
        let r = f.push_val(
            e,
            Inst::Bin {
                op: BinOp::Add,
                ty: Type::I32,
                a: Operand::ci32(i32::MAX),
                b: Operand::ci32(1),
            },
        );
        f.push(
            e,
            Inst::Bin { op: BinOp::Mul, ty: Type::I32, a: Operand::Reg(r), b: Operand::ci32(1) },
        );
        let n = run(&mut f);
        assert_eq!(n, 1, "one use rewritten");
        match f.block(e).insts[1].1 {
            Inst::Bin { a: Operand::Imm(Imm::Int(v, _)), .. } => {
                assert_eq!(v, i32::MIN as i64, "wrapping add folded");
            }
            ref other => panic!("not folded: {other:?}"),
        }
        verify(&f).unwrap();
    }

    #[test]
    fn division_by_constant_zero_is_not_folded() {
        let mut f = Function::new("k");
        let e = f.entry;
        let r = f.push_val(
            e,
            Inst::Bin { op: BinOp::Div, ty: Type::I32, a: Operand::ci32(7), b: Operand::ci32(0) },
        );
        f.push(
            e,
            Inst::Bin { op: BinOp::Add, ty: Type::I32, a: Operand::Reg(r), b: Operand::ci32(1) },
        );
        assert_eq!(run(&mut f), 0, "the trapping division must survive");
        assert!(matches!(f.block(e).insts[1].1, Inst::Bin { a: Operand::Reg(_), .. }));
    }

    #[test]
    fn folded_condition_reaches_the_branch() {
        let mut f = Function::new("k");
        let e = f.entry;
        let t = f.add_block("t");
        let x = f.add_block("x");
        let c = f.push_val(
            e,
            Inst::Bin { op: BinOp::Lt, ty: Type::I32, a: Operand::ci32(1), b: Operand::ci32(2) },
        );
        f.set_term(e, Term::Br { cond: Operand::Reg(c), t, f: x });
        assert_eq!(run(&mut f), 1, "branch condition rewritten to an immediate");
        match &f.block(e).term {
            Term::Br { cond: Operand::Imm(Imm::Int(1, Scalar::Bool)), .. } => {}
            other => panic!("{other:?}"),
        }
        verify(&f).unwrap();
    }

    #[test]
    fn float_fold_rounds_through_f32() {
        let mut f = Function::new("k");
        let e = f.entry;
        let r = f.push_val(
            e,
            Inst::Un { op: UnOp::Neg, ty: Type::F32, a: Operand::cf32(1.5) },
        );
        f.push(
            e,
            Inst::Bin { op: BinOp::Add, ty: Type::F32, a: Operand::Reg(r), b: Operand::cf32(0.25) },
        );
        assert_eq!(run(&mut f), 1);
        match f.block(e).insts[1].1 {
            Inst::Bin { a: Operand::Imm(Imm::Float(v, Scalar::F32)), .. } => assert_eq!(v, -1.5),
            ref other => panic!("{other:?}"),
        }
    }
}
