//! Implicit barriers for loops containing barriers — *b-loops* (§4.5).
//!
//! For every canonical loop that contains a barrier, three implicit
//! barriers are added so the parallel region formation is unambiguous:
//!
//! 1. at the end of the loop **pre-header** (synchronise before entering),
//! 2. at the **top of the header** (the paper's "after the PhiNode region"
//!    — our IR has no phis, so the header top is the equivalent point),
//! 3. at the end of the (single) **latch**, before its back-edge branch.
//!
//! The original loop branches are *not* replicated by the later work-item
//! loop materialisation, which is what enforces the iteration-level
//! lock-step semantics (Fig. 8, grey edges).

use crate::cl::error::{Error, Result};
use crate::ir::func::Function;
use crate::ir::inst::{BarrierKind, Inst};
use crate::ir::loops::{find_loops, Loop};

/// Instrument every loop that contains a barrier. Returns how many loops
/// were instrumented. `canonicalize` must have run.
pub fn run(f: &mut Function) -> Result<usize> {
    let mut count = 0;
    // Loops are discovered once; instrumentation preserves loop structure
    // (we only append/prepend instructions to existing blocks).
    let loops = find_loops(f);
    for l in &loops {
        let has_barrier = l.blocks.iter().any(|&b| f.block(b).has_barrier());
        if !has_barrier {
            continue;
        }
        instrument_loop(f, l)?;
        count += 1;
    }
    Ok(count)
}

/// Insert the three implicit b-loop barriers around loop `l`.
/// Idempotent: skips points that already hold a barrier.
pub fn instrument_loop(f: &mut Function, l: &Loop) -> Result<()> {
    let pre = l.preheader(f).ok_or_else(|| {
        Error::compile(format!(
            "b-loop with header bb{} has no dedicated preheader (canonicalize first)",
            l.header.0
        ))
    })?;
    if l.latches.len() != 1 {
        return Err(Error::compile(format!(
            "b-loop with header bb{} has {} latches (canonicalize first)",
            l.header.0,
            l.latches.len()
        )));
    }
    let latch = l.latches[0];
    // 1. End of preheader.
    if !ends_with_barrier(f, pre) {
        f.block_mut(pre).insts.push((None, Inst::Barrier { kind: BarrierKind::Implicit }));
    }
    // 2. Top of header.
    if !starts_with_barrier(f, l.header) {
        f.block_mut(l.header).insts.insert(0, (None, Inst::Barrier { kind: BarrierKind::Implicit }));
    }
    // 3. End of latch (before the back-edge branch).
    if !ends_with_barrier(f, latch) {
        f.block_mut(latch).insts.push((None, Inst::Barrier { kind: BarrierKind::Implicit }));
    }
    Ok(())
}

fn ends_with_barrier(f: &Function, b: crate::ir::inst::BlockId) -> bool {
    f.block(b).insts.last().map(|(_, i)| i.is_barrier()).unwrap_or(false)
}

fn starts_with_barrier(f: &Function, b: crate::ir::inst::BlockId) -> bool {
    f.block(b).insts.first().map(|(_, i)| i.is_barrier()).unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::compile;
    use crate::ir::cfg::unify_exits;
    use crate::ir::loops::canonicalize;
    use crate::ir::verify::{barrier_count, verify};

    fn prepared(src: &str) -> Function {
        let m = compile(src).unwrap();
        let mut f = m.kernels.into_iter().next().unwrap();
        unify_exits(&mut f);
        canonicalize(&mut f);
        f
    }

    #[test]
    fn instruments_barrier_loop() {
        let mut f = prepared(
            "__kernel void k(__global float *x, __local float *t, int n) {
                 for (int i = 0; i < n; i++) {
                     t[get_local_id(0)] = x[i];
                     barrier(CLK_LOCAL_MEM_FENCE);
                     x[i] = t[0];
                 }
             }",
        );
        let before = barrier_count(&f);
        let n = run(&mut f).unwrap();
        verify(&f).unwrap();
        assert_eq!(n, 1);
        assert_eq!(barrier_count(&f), before + 3, "preheader + header + latch barriers");
    }

    #[test]
    fn skips_barrier_free_loops() {
        let mut f = prepared(
            "__kernel void k(__global float *x, int n) {
                 for (int i = 0; i < n; i++) { x[i] = (float)i; }
             }",
        );
        assert_eq!(run(&mut f).unwrap(), 0);
    }

    #[test]
    fn nested_loop_with_barrier_instruments_both() {
        let mut f = prepared(
            "__kernel void k(__global float *x, int n) {
                 for (int i = 0; i < n; i++) {
                     for (int j = 0; j < n; j++) {
                         barrier(CLK_LOCAL_MEM_FENCE);
                         x[i * n + j] = 1.0f;
                     }
                 }
             }",
        );
        let n = run(&mut f).unwrap();
        verify(&f).unwrap();
        assert_eq!(n, 2, "both enclosing loops contain a barrier");
    }

    #[test]
    fn idempotent() {
        let mut f = prepared(
            "__kernel void k(__global float *x, int n) {
                 for (int i = 0; i < n; i++) {
                     barrier(CLK_LOCAL_MEM_FENCE);
                     x[i] = 1.0f;
                 }
             }",
        );
        run(&mut f).unwrap();
        let count = barrier_count(&f);
        run(&mut f).unwrap();
        assert_eq!(barrier_count(&f), count);
    }
}
