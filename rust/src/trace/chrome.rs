//! Chrome trace-event JSON exporter.
//!
//! Serialises drained [`TraceEvent`]s into the Chrome trace-event
//! format's "JSON object" flavour: a top-level object whose
//! `traceEvents` array holds one object per event. The output loads
//! directly in Perfetto (<https://ui.perfetto.dev>) and
//! `chrome://tracing`.
//!
//! Layout conventions (checked by [`super::json::validate_chrome_trace`]):
//!
//! * Host threads render as threads of process [`HOST_PID`], named via
//!   `process_name`/`thread_name` metadata (`M`) events.
//! * Every synthetic track from [`super::alloc_track`] renders as its
//!   own named "process", carrying the async (`b`/`n`/`e`) spans of one
//!   command queue or one device-group member.
//! * Timestamps (`ts`) and durations (`dur`) are microseconds with
//!   nanosecond precision (three decimal places), per the format spec.
//! * Flow arrows use the older `s`/`f` phases with `"bp":"e"` binding,
//!   which both viewers accept.

use std::fmt::Write as _;

use super::{ArgVal, Phase, TraceEvent, HOST_PID};

/// Escape a string for embedding in a JSON string literal.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Format nanoseconds as a microsecond JSON number with ns precision.
fn micros(ns: u64) -> String {
    format!("{}.{:03}", ns / 1000, ns % 1000)
}

fn push_arg(out: &mut String, key: &str, val: &ArgVal) {
    let _ = match val {
        ArgVal::U64(v) => write!(out, "\"{}\":{v}", escape(key)),
        ArgVal::I64(v) => write!(out, "\"{}\":{v}", escape(key)),
        ArgVal::F64(v) => {
            if v.is_finite() {
                write!(out, "\"{}\":{v}", escape(key))
            } else {
                write!(out, "\"{}\":null", escape(key))
            }
        }
        ArgVal::Str(v) => write!(out, "\"{}\":\"{}\"", escape(key), escape(v)),
    };
}

/// A `process_name` or `thread_name` metadata event.
fn push_meta(out: &mut String, kind: &str, pid: u64, tid: u64, name: &str) {
    let _ = write!(
        out,
        "{{\"ph\":\"M\",\"name\":\"{kind}\",\"pid\":{pid},\"tid\":{tid},\
         \"args\":{{\"name\":\"{}\"}}}}",
        escape(name)
    );
}

fn push_event(out: &mut String, ev: &TraceEvent) {
    let ph = match ev.phase {
        Phase::Complete => "X",
        Phase::Instant => "i",
        Phase::AsyncBegin => "b",
        Phase::AsyncInstant => "n",
        Phase::AsyncEnd => "e",
        Phase::FlowStart => "s",
        Phase::FlowEnd => "f",
    };
    let _ = write!(
        out,
        "{{\"ph\":\"{ph}\",\"cat\":\"{}\",\"name\":\"{}\",\"ts\":{},\"pid\":{},\"tid\":{}",
        escape(ev.cat),
        escape(&ev.name),
        micros(ev.ts_ns),
        ev.pid,
        ev.tid
    );
    match ev.phase {
        Phase::Complete => {
            let _ = write!(out, ",\"dur\":{}", micros(ev.dur_ns));
        }
        Phase::Instant => out.push_str(",\"s\":\"t\""),
        Phase::AsyncBegin | Phase::AsyncInstant | Phase::AsyncEnd | Phase::FlowStart => {
            let _ = write!(out, ",\"id\":{}", ev.id);
        }
        Phase::FlowEnd => {
            let _ = write!(out, ",\"id\":{},\"bp\":\"e\"", ev.id);
        }
    }
    if !ev.args.is_empty() {
        out.push_str(",\"args\":{");
        for (i, (k, v)) in ev.args.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            push_arg(out, k, v);
        }
        out.push('}');
    }
    out.push('}');
}

/// Serialise `events` (plus process/thread/track name metadata from the
/// tracer's registries) as a Chrome trace JSON document.
pub fn export_string(events: &[TraceEvent]) -> String {
    let mut out = String::with_capacity(events.len() * 140 + 1024);
    out.push_str("{\"displayTimeUnit\":\"ns\",\"traceEvents\":[\n");
    let mut first = true;
    let mut sep = |out: &mut String| {
        if !std::mem::take(&mut first) {
            out.push_str(",\n");
        }
    };
    sep(&mut out);
    push_meta(&mut out, "process_name", HOST_PID, 0, "poclrs");
    for (tid, name) in super::thread_names() {
        sep(&mut out);
        push_meta(&mut out, "thread_name", HOST_PID, tid, &name);
    }
    for (pid, name) in super::track_names() {
        sep(&mut out);
        push_meta(&mut out, "process_name", pid, 0, &name);
    }
    for ev in events {
        sep(&mut out);
        push_event(&mut out, ev);
    }
    out.push_str("\n]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::borrow::Cow;

    fn ev(phase: Phase, name: &'static str, ts_ns: u64, dur_ns: u64) -> TraceEvent {
        TraceEvent {
            phase,
            cat: "exec",
            name: Cow::Borrowed(name),
            ts_ns,
            dur_ns,
            pid: HOST_PID,
            tid: 3,
            id: 9,
            args: vec![("n", ArgVal::u(4)), ("what", ArgVal::s("a\"b"))],
        }
    }

    #[test]
    fn export_is_valid_json_with_expected_fields() {
        let events =
            vec![ev(Phase::Complete, "wg", 1_500, 2_250), ev(Phase::AsyncBegin, "cmd", 10, 0)];
        let text = export_string(&events);
        let v = crate::trace::json::parse(&text).expect("exporter output parses");
        let list = v.get("traceEvents").and_then(|t| t.as_array()).expect("traceEvents array");
        // Metadata first, then our two events.
        let xs: Vec<_> = list
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .collect();
        assert_eq!(xs.len(), 1);
        assert_eq!(xs[0].get("ts").and_then(|t| t.as_f64()), Some(1.5));
        assert_eq!(xs[0].get("dur").and_then(|t| t.as_f64()), Some(2.25));
        assert_eq!(
            xs[0].get("args").and_then(|a| a.get("what")).and_then(|w| w.as_str()),
            Some("a\"b")
        );
        let bs: Vec<_> = list
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("b"))
            .collect();
        assert_eq!(bs.len(), 1);
        assert_eq!(bs[0].get("id").and_then(|i| i.as_f64()), Some(9.0));
    }

    #[test]
    fn escape_handles_quotes_and_controls() {
        assert_eq!(escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(escape("\u{1}"), "\\u0001");
    }
}
