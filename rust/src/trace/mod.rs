//! Always-compiled-in runtime event tracer (the `POCL_TRACING` analog).
//!
//! Every layer of the runtime — command queues, the kernel compiler, the
//! persistent cache, the heterogeneous scheduler, and the execution
//! engines — emits spans into this module. Collection is cheap enough to
//! leave compiled in:
//!
//! * **Zero-cost when disabled** — every emit point first checks one
//!   relaxed atomic load ([`enabled`]); argument formatting and
//!   timestamping happen only when tracing is on.
//! * **Per-thread buffers** — an enabled emit appends to the calling
//!   thread's own buffer (one uncontended mutex per thread, locked only
//!   by that thread and by the final drain), so tracing never serialises
//!   the workers it observes.
//! * **Nanosecond timestamps** — monotonic, from one process-wide epoch
//!   taken when the tracer initialises.
//!
//! Events follow the Chrome trace-event model: complete spans (`X`, via
//! the RAII [`SpanGuard`]), instants (`i`), async spans (`b`/`n`/`e`,
//! grouped onto synthetic tracks allocated with [`alloc_track`] — one
//! per command queue and one per device-group member), and flow arrows
//! (`s`/`f`, the wait-list edges of the command DAG). [`chrome`] exports
//! the drained buffers as Chrome trace JSON (loadable in Perfetto or
//! `chrome://tracing`), [`json`] parses and schema-checks it back, and
//! [`metrics`] keeps the process-wide counter registry plus the
//! trace-derived per-phase durations. See `docs/tracing.md` for the
//! span taxonomy.

pub mod chrome;
pub mod json;
pub mod metrics;

use std::borrow::Cow;
use std::cell::RefCell;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Once, OnceLock};
use std::time::Instant;

/// Category of host-layer command/event lifecycle spans.
pub const CAT_QUEUE: &str = "queue";
/// Category of kernel-compiler phase spans.
pub const CAT_COMPILER: &str = "compiler";
/// Category of specialisation/persistent-cache spans.
pub const CAT_CACHE: &str = "cache";
/// Category of heterogeneous-scheduler spans.
pub const CAT_SCHED: &str = "sched";
/// Category of execution-engine spans.
pub const CAT_EXEC: &str = "exec";

/// The synthetic Chrome-trace process id all host threads render under.
/// Async tracks get their own ids from [`alloc_track`], starting above.
pub const HOST_PID: u64 = 1;

/// Chrome trace-event phase of one [`TraceEvent`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// `X` — a complete span with a duration, on the emitting thread.
    Complete,
    /// `i` — a thread-scoped instantaneous mark.
    Instant,
    /// `b` — start of an async span on a synthetic track.
    AsyncBegin,
    /// `n` — an instantaneous mark inside an async span.
    AsyncInstant,
    /// `e` — end of an async span.
    AsyncEnd,
    /// `s` — start of a flow arrow (emitted inside the producing span).
    FlowStart,
    /// `f` — end of a flow arrow (emitted inside the consuming span).
    FlowEnd,
}

/// A typed argument value attached to a trace event.
#[derive(Debug, Clone)]
pub enum ArgVal {
    /// Unsigned counter/size.
    U64(u64),
    /// Signed value.
    I64(i64),
    /// Floating-point value.
    F64(f64),
    /// String value.
    Str(String),
}

impl ArgVal {
    /// Shorthand for an unsigned argument.
    pub fn u(v: u64) -> ArgVal {
        ArgVal::U64(v)
    }

    /// Shorthand for a string argument.
    pub fn s(v: impl Into<String>) -> ArgVal {
        ArgVal::Str(v.into())
    }
}

/// One recorded event. Timestamps are nanoseconds since the tracer
/// epoch; the Chrome exporter converts them to microseconds.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Chrome phase of this event.
    pub phase: Phase,
    /// Category (one of the `CAT_*` constants, by convention).
    pub cat: &'static str,
    /// Event name (span label, kernel name, …).
    pub name: Cow<'static, str>,
    /// Start time in nanoseconds since the tracer epoch.
    pub ts_ns: u64,
    /// Duration in nanoseconds (complete spans only; 0 otherwise).
    pub dur_ns: u64,
    /// Chrome process id: [`HOST_PID`] for thread-local events, an
    /// [`alloc_track`] id for async events.
    pub pid: u64,
    /// Emitting thread's tracer-assigned id (0 for async-track events).
    pub tid: u64,
    /// Async-span / flow-arrow id (0 when unused).
    pub id: u64,
    /// Typed arguments.
    pub args: Vec<(&'static str, ArgVal)>,
}

/// One thread's event buffer. The hot path locks only its own mutex
/// (uncontended except against a concurrent drain).
struct ThreadBuf {
    tid: u64,
    name: String,
    events: Mutex<Vec<TraceEvent>>,
}

/// Process-wide tracer state behind a `OnceLock`.
struct Collector {
    epoch: Instant,
    threads: Mutex<Vec<Arc<ThreadBuf>>>,
    tracks: Mutex<Vec<(u64, String)>>,
    next_tid: AtomicU64,
    next_track: AtomicU64,
    next_id: AtomicU64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static ENV_INIT: Once = Once::new();

fn collector() -> &'static Collector {
    static C: OnceLock<Collector> = OnceLock::new();
    C.get_or_init(|| Collector {
        epoch: Instant::now(),
        threads: Mutex::new(Vec::new()),
        tracks: Mutex::new(Vec::new()),
        next_tid: AtomicU64::new(1),
        next_track: AtomicU64::new(HOST_PID + 1),
        next_id: AtomicU64::new(1),
    })
}

thread_local! {
    static TLS_BUF: RefCell<Option<Arc<ThreadBuf>>> = const { RefCell::new(None) };
}

/// Append an event to the calling thread's buffer, registering the
/// thread on first use. Safe to call during thread teardown (events
/// emitted after TLS destruction are silently dropped).
fn emit(mut ev: TraceEvent) {
    let _ = TLS_BUF.try_with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            let c = collector();
            let tid = c.next_tid.fetch_add(1, Ordering::Relaxed);
            let name = std::thread::current()
                .name()
                .map(str::to_string)
                .unwrap_or_else(|| format!("thread-{tid}"));
            let buf = Arc::new(ThreadBuf { tid, name, events: Mutex::new(Vec::new()) });
            c.threads.lock().unwrap().push(buf.clone());
            *slot = Some(buf);
        }
        let buf = slot.as_ref().unwrap();
        if ev.tid == 0 && ev.pid == HOST_PID {
            ev.tid = buf.tid;
        }
        buf.events.lock().unwrap().push(ev);
    });
}

/// Whether tracing is currently collecting. The first call initialises
/// the flag from `POCLRS_TRACE` (set to a file path = on); afterwards
/// this is a single relaxed atomic load — the entire disabled-path cost
/// of every instrumentation point.
pub fn enabled() -> bool {
    ENV_INIT.call_once(|| {
        if env_trace_path().is_some() {
            ENABLED.store(true, Ordering::Relaxed);
        }
    });
    ENABLED.load(Ordering::Relaxed)
}

/// Turn collection on or off programmatically (the CLI `--trace` flag,
/// tests). Overrides whatever `POCLRS_TRACE` said.
pub fn set_enabled(on: bool) {
    ENV_INIT.call_once(|| {});
    ENABLED.store(on, Ordering::Relaxed);
}

/// The trace output path requested via `POCLRS_TRACE`, if any. An empty
/// or whitespace value is invalid (warned once via [`crate::envcfg`]);
/// `0`/`off`/`no`/`false` explicitly disable tracing without a warning.
pub fn env_trace_path() -> Option<PathBuf> {
    let raw = std::env::var("POCLRS_TRACE").ok()?;
    if matches!(raw.to_ascii_lowercase().as_str(), "0" | "off" | "no" | "false") {
        return None;
    }
    crate::envcfg::parse_or_warn(
        "POCLRS_TRACE",
        Some(raw.as_str()),
        "a trace output file path, or 0/off",
        "tracing stays disabled",
        |s| {
            if s.trim().is_empty() {
                None
            } else {
                Some(PathBuf::from(s))
            }
        },
    )
}

/// Nanoseconds since the tracer epoch (monotonic).
pub fn now_ns() -> u64 {
    collector().epoch.elapsed().as_nanos() as u64
}

/// Allocate a fresh async-span / flow-arrow id (process-unique).
pub fn next_id() -> u64 {
    collector().next_id.fetch_add(1, Ordering::Relaxed)
}

/// Allocate a synthetic Chrome "process" track with a display name (one
/// per command queue, one per device-group member). The returned pid is
/// process-unique and never equals [`HOST_PID`].
pub fn alloc_track(name: impl Into<String>) -> u64 {
    let c = collector();
    let pid = c.next_track.fetch_add(1, Ordering::Relaxed);
    c.tracks.lock().unwrap().push((pid, name.into()));
    pid
}

/// RAII guard for a complete (`X`) span: records the start time on
/// construction and emits the event with its duration on drop. Inactive
/// guards (created while tracing is disabled) cost nothing on drop.
#[must_use = "a span guard measures until it is dropped"]
pub struct SpanGuard {
    active: bool,
    start_ns: u64,
    cat: &'static str,
    name: Cow<'static, str>,
    args: Vec<(&'static str, ArgVal)>,
}

impl SpanGuard {
    /// Attach an argument discovered mid-span (e.g. a lookup outcome).
    pub fn arg(&mut self, key: &'static str, val: ArgVal) {
        if self.active {
            self.args.push((key, val));
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let end = now_ns();
        emit(TraceEvent {
            phase: Phase::Complete,
            cat: self.cat,
            name: std::mem::replace(&mut self.name, Cow::Borrowed("")),
            ts_ns: self.start_ns,
            dur_ns: end.saturating_sub(self.start_ns),
            pid: HOST_PID,
            tid: 0,
            id: 0,
            args: std::mem::take(&mut self.args),
        });
    }
}

/// Open a complete span on the calling thread. Callers whose name or
/// arguments require allocation should guard the whole call with
/// [`enabled`] so the disabled path stays allocation-free.
pub fn span(cat: &'static str, name: impl Into<Cow<'static, str>>) -> SpanGuard {
    span_args(cat, name, Vec::new())
}

/// [`span`] with arguments attached up front.
pub fn span_args(
    cat: &'static str,
    name: impl Into<Cow<'static, str>>,
    args: Vec<(&'static str, ArgVal)>,
) -> SpanGuard {
    if !enabled() {
        return SpanGuard {
            active: false,
            start_ns: 0,
            cat,
            name: Cow::Borrowed(""),
            args: Vec::new(),
        };
    }
    SpanGuard { active: true, start_ns: now_ns(), cat, name: name.into(), args }
}

/// Emit a thread-scoped instantaneous mark.
pub fn instant(cat: &'static str, name: impl Into<Cow<'static, str>>) {
    if !enabled() {
        return;
    }
    emit(TraceEvent {
        phase: Phase::Instant,
        cat,
        name: name.into(),
        ts_ns: now_ns(),
        dur_ns: 0,
        pid: HOST_PID,
        tid: 0,
        id: 0,
        args: Vec::new(),
    });
}

fn async_event(
    phase: Phase,
    cat: &'static str,
    name: Cow<'static, str>,
    track: u64,
    id: u64,
    args: Vec<(&'static str, ArgVal)>,
) {
    emit(TraceEvent {
        phase,
        cat,
        name,
        ts_ns: now_ns(),
        dur_ns: 0,
        pid: track,
        tid: 0,
        id,
        args,
    });
}

/// Begin an async span on a synthetic track ([`alloc_track`]). Pair with
/// [`async_end`] using the same `cat`, `track`, and `id`.
pub fn async_begin(cat: &'static str, name: impl Into<Cow<'static, str>>, track: u64, id: u64) {
    async_begin_args(cat, name, track, id, Vec::new());
}

/// [`async_begin`] with arguments attached.
pub fn async_begin_args(
    cat: &'static str,
    name: impl Into<Cow<'static, str>>,
    track: u64,
    id: u64,
    args: Vec<(&'static str, ArgVal)>,
) {
    if !enabled() {
        return;
    }
    async_event(Phase::AsyncBegin, cat, name.into(), track, id, args);
}

/// Emit an instantaneous mark inside an open async span.
pub fn async_instant(cat: &'static str, name: impl Into<Cow<'static, str>>, track: u64, id: u64) {
    if !enabled() {
        return;
    }
    async_event(Phase::AsyncInstant, cat, name.into(), track, id, Vec::new());
}

/// End an async span begun with [`async_begin`].
pub fn async_end(cat: &'static str, name: impl Into<Cow<'static, str>>, track: u64, id: u64) {
    if !enabled() {
        return;
    }
    async_event(Phase::AsyncEnd, cat, name.into(), track, id, Vec::new());
}

/// Emit the producing end of a flow arrow (a wait-list edge): call
/// inside the span that *satisfies* the dependency.
pub fn flow_start(cat: &'static str, id: u64) {
    if !enabled() {
        return;
    }
    emit(TraceEvent {
        phase: Phase::FlowStart,
        cat,
        name: Cow::Borrowed("dep"),
        ts_ns: now_ns(),
        dur_ns: 0,
        pid: HOST_PID,
        tid: 0,
        id,
        args: Vec::new(),
    });
}

/// Emit the consuming end of a flow arrow: call inside the span that
/// *waited on* the dependency, after [`flow_start`] was emitted.
pub fn flow_end(cat: &'static str, id: u64) {
    if !enabled() {
        return;
    }
    emit(TraceEvent {
        phase: Phase::FlowEnd,
        cat,
        name: Cow::Borrowed("dep"),
        ts_ns: now_ns(),
        dur_ns: 0,
        pid: HOST_PID,
        tid: 0,
        id,
        args: Vec::new(),
    });
}

/// Drain every thread's buffer into one list sorted by start time.
/// Thread registrations (and their display names) survive the drain, so
/// a later export still names every track.
pub fn take_events() -> Vec<TraceEvent> {
    let c = collector();
    let mut out = Vec::new();
    for buf in c.threads.lock().unwrap().iter() {
        out.append(&mut buf.events.lock().unwrap());
    }
    out.sort_by_key(|e| e.ts_ns);
    out
}

/// Snapshot of registered host threads as `(tid, name)`.
pub fn thread_names() -> Vec<(u64, String)> {
    collector().threads.lock().unwrap().iter().map(|b| (b.tid, b.name.clone())).collect()
}

/// Snapshot of allocated synthetic tracks as `(pid, name)`.
pub fn track_names() -> Vec<(u64, String)> {
    collector().tracks.lock().unwrap().clone()
}

/// Drain all buffered events and write them to `path` as Chrome trace
/// JSON (the `POCLRS_TRACE` exit path; the CLI `--trace` flag exports
/// via [`chrome::export_string`] instead so it can share the drained
/// events with `--metrics-json`).
pub fn write_chrome(path: &std::path::Path) -> std::io::Result<()> {
    let events = take_events();
    std::fs::write(path, chrome::export_string(&events))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Global tracer state is shared across the whole test binary; unit
    // tests here only exercise the disabled path and pure helpers to
    // stay independent of `tests/trace_verify.rs`-style lifecycle tests.

    #[test]
    fn disabled_span_guard_is_inert() {
        if enabled() {
            return; // an env-driven trace run owns the global state
        }
        let before = thread_names().len();
        {
            let mut g = span(CAT_EXEC, "noop");
            g.arg("k", ArgVal::u(1));
        }
        instant(CAT_EXEC, "noop");
        flow_start(CAT_QUEUE, 7);
        flow_end(CAT_QUEUE, 7);
        // Nothing was emitted, so no thread registration happened either.
        assert_eq!(thread_names().len(), before);
    }

    #[test]
    fn track_allocation_is_unique_and_named() {
        let a = alloc_track("track-a");
        let b = alloc_track("track-b");
        assert_ne!(a, b);
        assert_ne!(a, HOST_PID);
        let names = track_names();
        assert!(names.iter().any(|(pid, n)| *pid == a && n == "track-a"));
        assert!(names.iter().any(|(pid, n)| *pid == b && n == "track-b"));
    }

    #[test]
    fn ids_are_monotonic() {
        let a = next_id();
        let b = next_id();
        assert!(b > a);
    }
}
