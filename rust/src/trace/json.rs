//! Minimal JSON parser and Chrome-trace schema validator.
//!
//! The crate is dependency-free, so the trace round-trip tooling (the
//! `poclrs trace check` CLI, `tests/trace_verify.rs`) carries its own
//! strict recursive-descent JSON parser plus the schema checks the
//! tracer's exporter promises:
//!
//! * every event object has `ph`, `name`, `pid`, `tid` (and `ts` for
//!   non-metadata phases, `dur` for `X`, `id` for async/flow phases),
//! * async begin/end events balance per `(pid, cat, id)`,
//! * complete (`X`) spans nest per `(pid, tid)` — stack discipline.

use std::collections::{BTreeSet, HashMap};

/// A parsed JSON value. Objects preserve key order (and duplicates) as
/// a `Vec` — ordering stability matters more here than lookup speed.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number, as `f64`.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<JsonValue>),
    /// An object, as ordered key/value pairs.
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Member of an object by key (first match), `None` otherwise.
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The key/value pairs, if this is an object.
    pub fn as_object(&self) -> Option<&[(String, JsonValue)]> {
        match self {
            JsonValue::Obj(v) => Some(v),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> String {
        format!("{msg} at byte {}", self.pos)
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: JsonValue) -> Result<JsonValue, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<JsonValue, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected `{}`", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn number(&mut self) -> Result<JsonValue, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("non-UTF-8 number"))?;
        text.parse::<f64>()
            .map(JsonValue::Num)
            .map_err(|_| format!("bad number `{text}` at byte {start}"))
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (d as char).to_digit(16).ok_or_else(|| self.err("bad \\u escape"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // Surrogate pair: a second \uXXXX must follow.
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else if (0xDC00..0xE000).contains(&hi) {
                            return Err(self.err("lone low surrogate"));
                        } else {
                            hi
                        };
                        out.push(
                            char::from_u32(cp).ok_or_else(|| self.err("bad \\u code point"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("raw control char in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    let len = match b {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad UTF-8 byte")),
                    };
                    let start = self.pos - 1;
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated UTF-8 sequence"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("bad UTF-8 sequence"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => {}
                Some(b']') => return Ok(JsonValue::Arr(items)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<JsonValue, String> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => {}
                Some(b'}') => return Ok(JsonValue::Obj(pairs)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

/// Parse a JSON document. Strict: trailing garbage is an error.
pub fn parse(src: &str) -> Result<JsonValue, String> {
    let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

/// What a validated trace contained (the `trace check` report).
#[derive(Debug, Default)]
pub struct TraceSummary {
    /// Total events, metadata included.
    pub events: usize,
    /// Complete (`X`) spans.
    pub complete: usize,
    /// Async spans (balanced `b`/`e` pairs).
    pub async_spans: usize,
    /// Distinct non-metadata categories seen.
    pub cats: BTreeSet<String>,
    /// Distinct `(pid, tid)` host-thread pairs seen on `X` events.
    pub threads: BTreeSet<(u64, u64)>,
}

fn req_num(ev: &JsonValue, key: &str, i: usize) -> Result<f64, String> {
    ev.get(key)
        .and_then(JsonValue::as_f64)
        .ok_or_else(|| format!("event {i}: missing numeric `{key}`"))
}

fn req_str<'v>(ev: &'v JsonValue, key: &str, i: usize) -> Result<&'v str, String> {
    ev.get(key)
        .and_then(JsonValue::as_str)
        .ok_or_else(|| format!("event {i}: missing string `{key}`"))
}

/// Validate a parsed document against the Chrome trace-event subset the
/// exporter emits (see module docs). Returns a content summary on
/// success.
pub fn validate_chrome_trace(doc: &JsonValue) -> Result<TraceSummary, String> {
    let events = doc
        .get("traceEvents")
        .and_then(JsonValue::as_array)
        .ok_or("top level must be an object with a `traceEvents` array")?;
    let mut sum = TraceSummary { events: events.len(), ..TraceSummary::default() };
    // (pid, cat, id) -> begin-count minus end-count.
    let mut open_async: HashMap<(u64, String, u64), i64> = HashMap::new();
    for (i, ev) in events.iter().enumerate() {
        if ev.as_object().is_none() {
            return Err(format!("event {i}: not an object"));
        }
        let ph = req_str(ev, "ph", i)?;
        req_str(ev, "name", i)?;
        let pid = req_num(ev, "pid", i)? as u64;
        let tid = req_num(ev, "tid", i)? as u64;
        if ph == "M" {
            let kind = req_str(ev, "name", i)?;
            if !matches!(kind, "process_name" | "thread_name") {
                return Err(format!("event {i}: unknown metadata `{kind}`"));
            }
            ev.get("args")
                .and_then(|a| a.get("name"))
                .and_then(JsonValue::as_str)
                .ok_or_else(|| format!("event {i}: metadata without args.name"))?;
            continue;
        }
        let ts = req_num(ev, "ts", i)?;
        if !ts.is_finite() || ts < 0.0 {
            return Err(format!("event {i}: bad ts {ts}"));
        }
        let cat = req_str(ev, "cat", i)?;
        if cat.is_empty() {
            return Err(format!("event {i}: empty cat"));
        }
        sum.cats.insert(cat.to_string());
        match ph {
            "X" => {
                let dur = req_num(ev, "dur", i)?;
                if !dur.is_finite() || dur < 0.0 {
                    return Err(format!("event {i}: bad dur {dur}"));
                }
                sum.complete += 1;
                sum.threads.insert((pid, tid));
            }
            "i" => {}
            "b" | "n" | "e" => {
                let id = req_num(ev, "id", i)? as u64;
                let slot = open_async.entry((pid, cat.to_string(), id)).or_insert(0);
                match ph {
                    "b" => {
                        *slot += 1;
                        sum.async_spans += 1;
                    }
                    "e" => {
                        *slot -= 1;
                        if *slot < 0 {
                            return Err(format!(
                                "event {i}: async end without begin (pid {pid}, id {id})"
                            ));
                        }
                    }
                    _ => {
                        if *slot <= 0 {
                            return Err(format!(
                                "event {i}: async instant outside a span (pid {pid}, id {id})"
                            ));
                        }
                    }
                }
            }
            "s" | "f" => {
                req_num(ev, "id", i)?;
            }
            other => return Err(format!("event {i}: unknown phase `{other}`")),
        }
    }
    if let Some(((pid, cat, id), n)) = open_async.iter().find(|(_, &n)| n != 0) {
        return Err(format!(
            "unbalanced async span: pid {pid}, cat {cat}, id {id} ({n} open)"
        ));
    }
    Ok(sum)
}

/// Check that complete (`X`) spans obey stack discipline per
/// `(pid, tid)`: a span that starts inside another must also end inside
/// it. Timestamp comparisons tolerate the exporter's microsecond
/// formatting at `EPS`.
pub fn check_nesting(doc: &JsonValue) -> Result<(), String> {
    const EPS: f64 = 1e-6;
    let events = doc
        .get("traceEvents")
        .and_then(JsonValue::as_array)
        .ok_or("top level must be an object with a `traceEvents` array")?;
    let mut per_thread: HashMap<(u64, u64), Vec<(f64, f64, String)>> = HashMap::new();
    for ev in events {
        if ev.get("ph").and_then(JsonValue::as_str) != Some("X") {
            continue;
        }
        let pid = ev.get("pid").and_then(JsonValue::as_f64).unwrap_or(0.0) as u64;
        let tid = ev.get("tid").and_then(JsonValue::as_f64).unwrap_or(0.0) as u64;
        let ts = ev.get("ts").and_then(JsonValue::as_f64).unwrap_or(0.0);
        let dur = ev.get("dur").and_then(JsonValue::as_f64).unwrap_or(0.0);
        let name = ev.get("name").and_then(JsonValue::as_str).unwrap_or("").to_string();
        per_thread.entry((pid, tid)).or_default().push((ts, ts + dur, name));
    }
    for ((pid, tid), mut spans) in per_thread {
        // Parents sort before their children: by start ascending, then
        // by end descending (the longer span encloses).
        spans.sort_by(|a, b| a.0.total_cmp(&b.0).then(b.1.total_cmp(&a.1)));
        let mut stack: Vec<(f64, String)> = Vec::new();
        for (ts, end, name) in spans {
            while let Some((top_end, _)) = stack.last() {
                if *top_end <= ts + EPS {
                    stack.pop();
                } else {
                    break;
                }
            }
            if let Some((top_end, top_name)) = stack.last() {
                if end > top_end + EPS {
                    return Err(format!(
                        "span `{name}` [{ts}, {end}] overlaps `{top_name}` \
                         (ends {top_end}) on thread {pid}/{tid}"
                    ));
                }
            }
            stack.push((end, name));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_arrays_objects() {
        assert_eq!(parse("null").unwrap(), JsonValue::Null);
        assert_eq!(parse(" true ").unwrap(), JsonValue::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), JsonValue::Num(-150.0));
        assert_eq!(parse(r#""a\nbA""#).unwrap(), JsonValue::Str("a\nbA".into()));
        let v = parse(r#"{"a":[1,2,{"b":"c"}],"d":null}"#).unwrap();
        assert_eq!(v.get("d"), Some(&JsonValue::Null));
        let arr = v.get("a").and_then(JsonValue::as_array).unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").and_then(JsonValue::as_str), Some("c"));
    }

    #[test]
    fn rejects_malformed_documents() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":1} extra").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn roundtrips_utf8_and_surrogates() {
        assert_eq!(parse("\"π≈3\"").unwrap(), JsonValue::Str("π≈3".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), JsonValue::Str("😀".into()));
        assert!(parse(r#""\ud83d""#).is_err(), "lone surrogate rejected");
    }

    #[test]
    fn validator_rejects_schema_violations() {
        // Not a trace document at all.
        assert!(validate_chrome_trace(&parse("[1,2]").unwrap()).is_err());
        // Event without a phase.
        let bad = parse(r#"{"traceEvents":[{"name":"x","pid":1,"tid":1}]}"#).unwrap();
        assert!(validate_chrome_trace(&bad).is_err());
        // Async end without a begin.
        let bad = parse(
            r#"{"traceEvents":[
                {"ph":"e","cat":"queue","name":"x","ts":1,"pid":2,"tid":0,"id":5}
            ]}"#,
        )
        .unwrap();
        assert!(validate_chrome_trace(&bad).is_err());
        // Unbalanced async begin.
        let bad = parse(
            r#"{"traceEvents":[
                {"ph":"b","cat":"queue","name":"x","ts":1,"pid":2,"tid":0,"id":5}
            ]}"#,
        )
        .unwrap();
        assert!(validate_chrome_trace(&bad).is_err());
    }

    #[test]
    fn validator_accepts_a_wellformed_trace() {
        let good = parse(
            r#"{"traceEvents":[
                {"ph":"M","name":"process_name","pid":1,"tid":0,"args":{"name":"p"}},
                {"ph":"X","cat":"exec","name":"wg","ts":1.0,"dur":2.0,"pid":1,"tid":3},
                {"ph":"b","cat":"queue","name":"cmd","ts":0.5,"pid":2,"tid":0,"id":7},
                {"ph":"n","cat":"queue","name":"running","ts":1.0,"pid":2,"tid":0,"id":7},
                {"ph":"e","cat":"queue","name":"cmd","ts":4.0,"pid":2,"tid":0,"id":7},
                {"ph":"s","cat":"queue","name":"dep","ts":3.0,"pid":1,"tid":3,"id":7},
                {"ph":"f","cat":"queue","name":"dep","ts":3.5,"pid":1,"tid":4,"id":7,"bp":"e"}
            ]}"#,
        )
        .unwrap();
        let sum = validate_chrome_trace(&good).expect("valid");
        assert_eq!(sum.complete, 1);
        assert_eq!(sum.async_spans, 1);
        assert!(sum.cats.contains("exec") && sum.cats.contains("queue"));
    }

    #[test]
    fn nesting_check_accepts_stacks_and_rejects_overlap() {
        let nested = parse(
            r#"{"traceEvents":[
                {"ph":"X","cat":"c","name":"outer","ts":0.0,"dur":10.0,"pid":1,"tid":1},
                {"ph":"X","cat":"c","name":"inner","ts":2.0,"dur":3.0,"pid":1,"tid":1},
                {"ph":"X","cat":"c","name":"sibling","ts":6.0,"dur":2.0,"pid":1,"tid":1}
            ]}"#,
        )
        .unwrap();
        check_nesting(&nested).expect("stacked spans nest");
        let overlap = parse(
            r#"{"traceEvents":[
                {"ph":"X","cat":"c","name":"a","ts":0.0,"dur":5.0,"pid":1,"tid":1},
                {"ph":"X","cat":"c","name":"b","ts":3.0,"dur":5.0,"pid":1,"tid":1}
            ]}"#,
        )
        .unwrap();
        assert!(check_nesting(&overlap).is_err(), "straddling spans rejected");
        // Different threads never constrain each other.
        let cross = parse(
            r#"{"traceEvents":[
                {"ph":"X","cat":"c","name":"a","ts":0.0,"dur":5.0,"pid":1,"tid":1},
                {"ph":"X","cat":"c","name":"b","ts":3.0,"dur":5.0,"pid":1,"tid":2}
            ]}"#,
        )
        .unwrap();
        check_nesting(&cross).expect("threads are independent");
    }
}
