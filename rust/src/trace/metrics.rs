//! Process-wide metrics registry and trace-derived phase totals.
//!
//! Two complementary views feed the `--metrics-json` snapshot:
//!
//! * [`MetricsRegistry`] — named monotonic counters bumped from anywhere
//!   in the runtime via [`add`] (queue commands issued, cache hits,
//!   scheduler steals, …). Always on: a counter bump is one short
//!   mutex-protected map update, orders of magnitude below the work it
//!   counts.
//! * [`phase_totals`] — aggregates drained complete spans by
//!   `(category, name)` into count / total / max durations, turning the
//!   raw trace into the per-phase timing table the autotuning items
//!   need.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

use super::{Phase, TraceEvent};

/// A set of named monotonic `u64` counters.
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<&'static str, u64>>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry { counters: Mutex::new(BTreeMap::new()) }
    }

    /// Add `delta` to the counter `name`, creating it at zero first.
    pub fn add(&self, name: &'static str, delta: u64) {
        let mut map = self.counters.lock().unwrap_or_else(|e| e.into_inner());
        *map.entry(name).or_insert(0) += delta;
    }

    /// Snapshot all counters, sorted by name.
    pub fn snapshot(&self) -> Vec<(&'static str, u64)> {
        let map = self.counters.lock().unwrap_or_else(|e| e.into_inner());
        map.iter().map(|(k, v)| (*k, *v)).collect()
    }

    /// Clear every counter (tests).
    pub fn reset(&self) {
        self.counters.lock().unwrap_or_else(|e| e.into_inner()).clear();
    }
}

impl Default for MetricsRegistry {
    fn default() -> MetricsRegistry {
        MetricsRegistry::new()
    }
}

/// The process-wide registry every runtime layer reports into.
pub fn global() -> &'static MetricsRegistry {
    static G: OnceLock<MetricsRegistry> = OnceLock::new();
    G.get_or_init(MetricsRegistry::new)
}

/// Bump a counter on the [`global`] registry.
pub fn add(name: &'static str, delta: u64) {
    global().add(name, delta);
}

/// Aggregated durations of one `(category, name)` span class.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PhaseTotal {
    /// Span category (`queue`, `compiler`, `cache`, `sched`, `exec`).
    pub cat: &'static str,
    /// Span name.
    pub name: String,
    /// How many spans of this class were recorded.
    pub count: u64,
    /// Sum of their durations, nanoseconds.
    pub total_ns: u64,
    /// Longest single span, nanoseconds.
    pub max_ns: u64,
}

/// Aggregate complete (`X`) spans by `(category, name)`, sorted by
/// category then name. Async/instant/flow events carry no duration and
/// are skipped.
pub fn phase_totals(events: &[TraceEvent]) -> Vec<PhaseTotal> {
    let mut map: BTreeMap<(&'static str, &str), (u64, u64, u64)> = BTreeMap::new();
    for ev in events {
        if ev.phase != Phase::Complete {
            continue;
        }
        let slot = map.entry((ev.cat, ev.name.as_ref())).or_insert((0, 0, 0));
        slot.0 += 1;
        slot.1 += ev.dur_ns;
        slot.2 = slot.2.max(ev.dur_ns);
    }
    map.into_iter()
        .map(|((cat, name), (count, total_ns, max_ns))| PhaseTotal {
            cat,
            name: name.to_string(),
            count,
            total_ns,
            max_ns,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::HOST_PID;
    use std::borrow::Cow;

    #[test]
    fn registry_accumulates_and_snapshots_sorted() {
        let r = MetricsRegistry::new();
        r.add("b.two", 2);
        r.add("a.one", 1);
        r.add("b.two", 3);
        assert_eq!(r.snapshot(), vec![("a.one", 1), ("b.two", 5)]);
        r.reset();
        assert!(r.snapshot().is_empty());
    }

    #[test]
    fn phase_totals_aggregate_complete_spans_only() {
        let mk = |phase, name: &'static str, dur_ns| TraceEvent {
            phase,
            cat: crate::trace::CAT_COMPILER,
            name: Cow::Borrowed(name),
            ts_ns: 0,
            dur_ns,
            pid: HOST_PID,
            tid: 1,
            id: 0,
            args: Vec::new(),
        };
        let events = vec![
            mk(Phase::Complete, "opt.dce", 10),
            mk(Phase::Complete, "opt.dce", 30),
            mk(Phase::Complete, "frontend", 5),
            mk(Phase::Instant, "opt.dce", 99),
        ];
        let totals = phase_totals(&events);
        assert_eq!(totals.len(), 2);
        let dce = totals.iter().find(|t| t.name == "opt.dce").unwrap();
        assert_eq!((dce.count, dce.total_ns, dce.max_ns), (2, 40, 30));
        let fe = totals.iter().find(|t| t.name == "frontend").unwrap();
        assert_eq!((fe.count, fe.total_ns, fe.max_ns), (1, 5, 5));
    }
}
