//! pocl-rs CLI: device discovery, kernel compilation inspection, suite
//! runs, and persistent kernel-cache management.
//!
//! ```text
//! poclrs devices                      # Table 1 capability table
//! poclrs run <App> [device] [--stats] [--opt N]  # run + verify one suite app
//! poclrs run <App> --devices a,b,c [--ratios r1,r2,r3]  # heterogeneous group run
//! poclrs run <App> --trace out.json [--metrics-json m.json]  # traced run
//! poclrs compile <file.cl> [LX]       # show compile stats + IR for a kernel
//! poclrs suite [device]               # run + verify the whole suite
//! poclrs trace check <file.json>      # schema-validate an emitted trace
//! poclrs cache ls                     # list persistent kernel-cache entries
//! poclrs cache stats                  # cache directory, size, hit counters
//! poclrs cache clear                  # drop every cached kernel binary
//! ```
//!
//! `--stats` prints the uniformity/divergence compile counters, the
//! mid-level optimizer per-pass counters (kcc/opt/), the
//! specialisation-cache counters (memory/disk hits vs compiles), and the
//! engine dispatch counters (gangs, diverged, vectorised/uniform/per-lane
//! and bytecode instruction dispatches) for the run. On a device group
//! it also prints the per-member scheduler breakdown (groups executed,
//! chunks pulled, steals, busy time, imbalance ratio).
//!
//! `--devices a,b,c` co-executes every launch across the named platform
//! devices as one heterogeneous group (`sched::DeviceGroup`). Without
//! `--ratios` the group uses the dynamic chunked self-scheduler;
//! `--ratios r1,r2,...` pins a static proportional split instead.
//!
//! `--opt N` (N = 0/1/2, default 2) selects the optimizer level; it sets
//! `POCLRS_OPT` before any device is created, so every device's
//! `CompileOptions` — and therefore every cache key — reflects it.
//!
//! `--trace FILE` (or the `POCLRS_TRACE=FILE` environment variable, which
//! also works for `suite` and every other subcommand) enables the runtime
//! tracer and writes a Chrome trace-event JSON file loadable in Perfetto /
//! `chrome://tracing`. `--metrics-json FILE` writes a merged metrics
//! snapshot (launch/compile/cache/sched counters plus trace-derived phase
//! durations). `trace check <file>` schema-validates an emitted trace.
//!
//! Environment: `POCLRS_OPT` sets the optimizer level, `POCLRS_CACHE_DIR`
//! relocates the persistent kernel cache (default `~/.cache/poclrs`),
//! `POCLRS_CACHE_MAX_BYTES` caps its size (default 256 MiB),
//! `POCLRS_CACHE=0` disables it, and `POCLRS_TRACE=FILE` enables tracing
//! and names the output file.

use std::sync::Arc;

use poclrs::cache;
use poclrs::cl::Platform;
use poclrs::devices::Device;
use poclrs::kcc::{compile_workgroup, CompileOptions};
use poclrs::sched::{Dynamic, SchedPolicy, StaticSplit};
use poclrs::suite::{all_apps, app_by_name, runner, SizeClass};

const USAGE: &str =
    "usage: poclrs devices | run <App> [device] [--stats] [--opt N] [--trace FILE] [--metrics-json FILE] [--devices a,b,c [--ratios r1,r2,...]] | suite [device] | compile <file.cl> [LX] | trace check <file.json> | cache ls|stats|clear";

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let platform = Platform::default_platform();
    // Set when a subcommand already wrote the trace itself, so the
    // end-of-main POCLRS_TRACE flush doesn't emit a second (empty) file.
    let mut trace_written = false;
    match args.first().map(|s| s.as_str()) {
        Some("devices") => {
            println!("platform `{}`\n{}", platform.name, platform.capability_table());
        }
        Some("run") => {
            let mut rest: Vec<&str> = args[1..].iter().map(|s| s.as_str()).collect();
            let want_stats = if let Some(i) = rest.iter().position(|a| *a == "--stats") {
                rest.remove(i);
                true
            } else {
                false
            };
            if let Some(i) = rest.iter().position(|a| *a == "--opt") {
                let lvl = rest
                    .get(i + 1)
                    .and_then(|s| s.parse::<u32>().ok())
                    .and_then(poclrs::kcc::OptLevel::from_u32)
                    .ok_or_else(|| String::from("--opt takes 0, 1, or 2"))?;
                rest.drain(i..=i + 1);
                // Devices read POCLRS_OPT via CompileOptions::default();
                // none has been created yet, so the level reaches all of
                // them (and every cache key).
                std::env::set_var("POCLRS_OPT", lvl.as_u32().to_string());
            }
            let mut trace_out: Option<String> =
                if let Some(i) = rest.iter().position(|a| *a == "--trace") {
                    let path = rest
                        .get(i + 1)
                        .ok_or_else(|| String::from("--trace takes an output file path"))?
                        .to_string();
                    rest.drain(i..=i + 1);
                    Some(path)
                } else {
                    None
                };
            let metrics_out: Option<String> =
                if let Some(i) = rest.iter().position(|a| *a == "--metrics-json") {
                    let path = rest
                        .get(i + 1)
                        .ok_or_else(|| String::from("--metrics-json takes an output file path"))?
                        .to_string();
                    rest.drain(i..=i + 1);
                    Some(path)
                } else {
                    None
                };
            if trace_out.is_some() || metrics_out.is_some() {
                // Enable before any device/queue exists so every span —
                // including compiles triggered by the first launch — lands
                // in the buffer.
                poclrs::trace::set_enabled(true);
            }
            if trace_out.is_none() && poclrs::trace::enabled() {
                // POCLRS_TRACE=FILE without --trace: this arm drains the
                // buffer (for --metrics-json), so it must also write the
                // env-requested trace from the same drain.
                trace_out = poclrs::trace::env_trace_path().map(|p| p.display().to_string());
            }
            let group_names: Option<Vec<String>> =
                if let Some(i) = rest.iter().position(|a| *a == "--devices") {
                    let list = rest
                        .get(i + 1)
                        .ok_or_else(|| String::from("--devices takes a comma-separated list"))?
                        .split(',')
                        .map(str::to_string)
                        .collect();
                    rest.drain(i..=i + 1);
                    Some(list)
                } else {
                    None
                };
            let ratios: Option<Vec<f64>> =
                if let Some(i) = rest.iter().position(|a| *a == "--ratios") {
                    let list = rest
                        .get(i + 1)
                        .ok_or_else(|| String::from("--ratios takes a comma-separated list"))?
                        .split(',')
                        .map(|s| {
                            s.parse::<f64>()
                                .map_err(|_| format!("bad ratio `{s}` (expected a number)"))
                        })
                        .collect::<Result<Vec<f64>, String>>()?;
                    rest.drain(i..=i + 1);
                    Some(list)
                } else {
                    None
                };
            let name = *rest
                .first()
                .ok_or_else(|| String::from("usage: run <App> [device] [--stats]"))?;
            let (device, dev) = match &group_names {
                Some(names) => {
                    let refs: Vec<&str> = names.iter().map(String::as_str).collect();
                    let policy: Arc<dyn SchedPolicy> = match &ratios {
                        Some(r) => Arc::new(StaticSplit::new(r.clone())),
                        None => Arc::new(Dynamic::new()),
                    };
                    let group = platform.group(&refs, policy)?;
                    let label = group.info().name;
                    (Arc::new(group) as Arc<dyn Device>, label)
                }
                None => {
                    if ratios.is_some() {
                        return Err("--ratios requires --devices".into());
                    }
                    let dev = rest.get(1).copied().unwrap_or("pthread-gang(8)");
                    (platform.find_device(dev)?, dev.to_string())
                }
            };
            let app = app_by_name(name, SizeClass::Bench)
                .ok_or_else(|| format!("no app named `{name}`"))?;
            let r = runner::run_and_verify(&app, device.clone())?;
            println!(
                "{name}: OK on {dev} ({} work-groups, {:?} kernel time)",
                r.stats.workgroups, r.kernel_time
            );
            if want_stats {
                // Compile-side counters come straight from the run's
                // program cache — the exact work-group functions the
                // launches used, with zero re-compilation.
                for (spec, wgf) in r.program.cached_specializations() {
                    println!(
                        "compile `{}` @ {:?}: regions={} uniform slots={} uniform regs={} divergent regions={} bytecode regions={} fused={} insts={} jit regions={} jit insts={} jit fallbacks={}",
                        spec.kernel,
                        spec.local,
                        wgf.stats.regions,
                        wgf.stats.uniform_slots,
                        wgf.stats.uniform_regs,
                        wgf.stats.divergent_regions,
                        wgf.stats.bytecode_regions,
                        wgf.stats.bytecode_fused,
                        wgf.stats.bytecode_insts,
                        wgf.stats.jit_regions,
                        wgf.stats.jit_insts,
                        wgf.stats.jit_fallbacks,
                    );
                    let o = &wgf.stats.opt;
                    println!(
                        "opt O{} `{}`: insts {} -> {} ({} removed), blocks {} -> {}, {} iters | cfg={} fold={} alg={} prop={} cse={} loadfwd={} dce={}",
                        spec.opts.opt_level.as_u32(),
                        spec.kernel,
                        o.insts_before,
                        o.insts_after,
                        o.insts_removed(),
                        o.blocks_before,
                        o.blocks_after,
                        o.iterations,
                        o.cfg_simplified,
                        o.folded,
                        o.algebraic,
                        o.propagated,
                        o.cse_hits,
                        o.loads_forwarded,
                        o.dce_removed,
                    );
                }
                let c = r.program.cache_stats();
                println!(
                    "cache: memory-hits={} disk-hits={} compiles={}",
                    c.memory_hits, c.disk_hits, c.misses
                );
                if let Some(disk) = cache::default_cache() {
                    let s = disk.stats();
                    println!(
                        "cache disk [{}]: hits={} misses={} read={}B written={}B evictions={}",
                        disk.dir().display(),
                        s.hits,
                        s.misses,
                        s.bytes_read,
                        s.bytes_written,
                        s.evictions,
                    );
                }
                // Engine-side counters for the whole run.
                let s = &r.stats;
                println!(
                    "exec: workgroups={} gangs={} diverged={} dispatches={} (vectorised={} uniform={} per-lane={} bytecode={}) bytecode-gangs={} fallbacks={} jit-insts={} jit-gangs={} jit-fallbacks={}",
                    s.workgroups,
                    s.gangs,
                    s.diverged_gangs,
                    s.dispatches(),
                    s.vector_insts,
                    s.uniform_insts,
                    s.lane_insts,
                    s.bytecode_insts,
                    s.bytecode_gangs,
                    s.bytecode_fallbacks,
                    s.jit_insts,
                    s.jit_gangs,
                    s.jit_fallbacks,
                );
                // Per-member scheduler breakdown (device groups only).
                if let Some(sc) = &r.sched {
                    println!(
                        "sched [{}] split-dim={} steals={} imbalance={:.2}",
                        sc.policy,
                        sc.split_dim,
                        sc.steals(),
                        sc.imbalance(),
                    );
                    for d in &sc.devices {
                        println!(
                            "  {:<24} groups={:>7} chunks={:>5} steals={:>4} busy={:>10.2?} dispatches={}",
                            d.name,
                            d.groups,
                            d.chunks,
                            d.steals,
                            std::time::Duration::from_nanos(d.busy_ns),
                            d.stats.dispatches(),
                        );
                    }
                }
            }
            if trace_out.is_some() || metrics_out.is_some() {
                // One drain serves both exporters: the event list feeds the
                // Chrome JSON verbatim and the phase-duration aggregation.
                let events = poclrs::trace::take_events();
                if let Some(path) = &trace_out {
                    std::fs::write(path, poclrs::trace::chrome::export_string(&events))?;
                    println!("trace: {} events written to {path}", events.len());
                    trace_written = true;
                }
                if let Some(path) = &metrics_out {
                    std::fs::write(path, metrics_report(name, &dev, &r, &events))?;
                    println!("metrics: written to {path}");
                }
            }
        }
        Some("suite") => {
            let dev = args.get(1).map(|s| s.as_str()).unwrap_or("pthread-gang(8)");
            let device = platform.find_device(dev)?;
            for app in all_apps(SizeClass::Small) {
                match runner::run_and_verify(&app, Arc::clone(&device)) {
                    Ok(r) => println!("{:<22} OK   {:>8.2?}", app.name, r.kernel_time),
                    Err(e) => println!("{:<22} FAIL {e}", app.name),
                }
            }
        }
        Some("compile") => {
            let path =
                args.get(1).ok_or_else(|| String::from("usage: compile <file.cl> [LX]"))?;
            let lx: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8);
            let src = std::fs::read_to_string(path)?;
            let module = poclrs::frontend::compile(&src)?;
            for k in &module.kernels {
                let wgf = compile_workgroup(k, [lx, 1, 1], &CompileOptions::default())?;
                println!("kernel `{}` @ local [{lx},1,1]: {:?}\n", k.name, wgf.stats);
                println!("--- region form ---\n{}", poclrs::ir::print::print_function(&wgf.reg_fn));
                println!("--- WI-loop form ---\n{}", poclrs::ir::print::print_function(&wgf.loop_fn));
            }
        }
        Some("trace") => {
            let sub = args.get(1).map(|s| s.as_str()).unwrap_or("");
            match sub {
                "check" => {
                    let path = args
                        .get(2)
                        .ok_or_else(|| String::from("usage: trace check <file.json>"))?;
                    let text = std::fs::read_to_string(path)?;
                    let doc = poclrs::trace::json::parse(&text)
                        .map_err(|e| format!("{path}: not valid JSON: {e}"))?;
                    let sum = poclrs::trace::json::validate_chrome_trace(&doc)
                        .map_err(|e| format!("{path}: schema violation: {e}"))?;
                    poclrs::trace::json::check_nesting(&doc)
                        .map_err(|e| format!("{path}: span nesting violation: {e}"))?;
                    println!(
                        "{path}: OK — {} events ({} complete spans, {} async spans) on {} threads; categories: {}",
                        sum.events,
                        sum.complete,
                        sum.async_spans,
                        sum.threads.len(),
                        sum.cats.iter().cloned().collect::<Vec<_>>().join(","),
                    );
                }
                other => {
                    eprintln!("unknown trace subcommand `{other}`\n{USAGE}");
                }
            }
        }
        Some("cache") => {
            let sub = args.get(1).map(|s| s.as_str()).unwrap_or("stats");
            let disk = cache::DiskCache::at(cache::DiskCache::default_dir())?;
            match sub {
                "ls" => {
                    let entries = disk.entries()?;
                    if entries.is_empty() {
                        println!("cache [{}] is empty", disk.dir().display());
                    } else {
                        println!("cache [{}]: {} entries", disk.dir().display(), entries.len());
                        for e in &entries {
                            let what = match (&e.kernel, e.local_size) {
                                (Some(k), Some(l)) => format!("kernel `{k}` @ {l:?}"),
                                _ => "unreadable (stale format or corrupt)".to_string(),
                            };
                            println!("  {}  {:>8} B  {}", e.key, e.bytes, what);
                        }
                    }
                }
                "clear" => {
                    let n = disk.clear()?;
                    println!("removed {n} entries from {}", disk.dir().display());
                }
                "stats" => {
                    let entries = disk.entries()?;
                    let total: u64 = entries.iter().map(|e| e.bytes).sum();
                    println!(
                        "dir:     {}\nentries: {}\nbytes:   {total} (cap {})\nformat:  poclbin v{}",
                        disk.dir().display(),
                        entries.len(),
                        disk.max_bytes(),
                        cache::POCLBIN_VERSION,
                    );
                }
                other => {
                    eprintln!("unknown cache subcommand `{other}`\n{USAGE}");
                }
            }
        }
        _ => {
            eprintln!("{USAGE}");
        }
    }
    // POCLRS_TRACE flush for subcommands that don't drain the buffer
    // themselves (`suite`, `compile`, ...). `trace check` is excluded so
    // validating a file never overwrites it.
    if !trace_written
        && !matches!(args.first().map(|s| s.as_str()), Some("trace"))
        && poclrs::trace::enabled()
    {
        if let Some(path) = poclrs::trace::env_trace_path() {
            poclrs::trace::write_chrome(&path)?;
            eprintln!("poclrs: trace written to {}", path.display());
        }
    }
    Ok(())
}

/// Render the merged metrics snapshot for `--metrics-json`: the run's
/// [`LaunchStats`](poclrs::devices::LaunchStats), per-specialisation
/// compile/optimizer counters, program- and disk-cache counters, the
/// scheduler breakdown (device groups only), the process-wide metric
/// counters, and per-phase durations aggregated from the trace buffer.
fn metrics_report(
    app: &str,
    device: &str,
    r: &runner::RunResult,
    events: &[poclrs::trace::TraceEvent],
) -> String {
    use poclrs::trace::chrome::escape;
    use std::fmt::Write as _;
    let mut out = String::new();
    let s = &r.stats;
    let _ = write!(
        out,
        "{{\n  \"app\": \"{}\",\n  \"device\": \"{}\",\n  \"kernel_time_ns\": {},\n",
        escape(app),
        escape(device),
        r.kernel_time.as_nanos(),
    );
    let _ = write!(
        out,
        "  \"launch\": {{\"workgroups\": {}, \"gangs\": {}, \"diverged_gangs\": {}, \"dispatches\": {}, \"vector_insts\": {}, \"uniform_insts\": {}, \"lane_insts\": {}, \"bytecode_insts\": {}, \"jit_insts\": {}}},\n",
        s.workgroups,
        s.gangs,
        s.diverged_gangs,
        s.dispatches(),
        s.vector_insts,
        s.uniform_insts,
        s.lane_insts,
        s.bytecode_insts,
        s.jit_insts,
    );
    out.push_str("  \"compile\": [\n");
    let specs = r.program.cached_specializations();
    for (i, (spec, wgf)) in specs.iter().enumerate() {
        let o = &wgf.stats.opt;
        let _ = write!(
            out,
            "    {{\"kernel\": \"{}\", \"local\": [{},{},{}], \"opt_level\": {}, \"regions\": {}, \"uniform_regs\": {}, \"divergent_regions\": {}, \"bytecode_regions\": {}, \"jit_regions\": {}, \"opt\": {{\"insts_before\": {}, \"insts_after\": {}, \"iterations\": {}}}}}{}\n",
            escape(&spec.kernel),
            spec.local[0],
            spec.local[1],
            spec.local[2],
            spec.opts.opt_level.as_u32(),
            wgf.stats.regions,
            wgf.stats.uniform_regs,
            wgf.stats.divergent_regions,
            wgf.stats.bytecode_regions,
            wgf.stats.jit_regions,
            o.insts_before,
            o.insts_after,
            o.iterations,
            if i + 1 < specs.len() { "," } else { "" },
        );
    }
    out.push_str("  ],\n");
    let c = r.program.cache_stats();
    let _ = write!(
        out,
        "  \"program_cache\": {{\"memory_hits\": {}, \"disk_hits\": {}, \"compiles\": {}}},\n",
        c.memory_hits, c.disk_hits, c.misses,
    );
    match cache::default_cache() {
        Some(disk) => {
            let d = disk.stats();
            let _ = write!(
                out,
                "  \"disk_cache\": {{\"hits\": {}, \"misses\": {}, \"rejected\": {}, \"writes\": {}, \"bytes_read\": {}, \"bytes_written\": {}, \"evictions\": {}}},\n",
                d.hits, d.misses, d.rejected, d.writes, d.bytes_read, d.bytes_written, d.evictions,
            );
        }
        None => out.push_str("  \"disk_cache\": null,\n"),
    }
    match &r.sched {
        Some(sc) => {
            let _ = write!(
                out,
                "  \"sched\": {{\"policy\": \"{}\", \"split_dim\": {}, \"steals\": {}, \"imbalance\": {:.4}, \"devices\": [",
                escape(&sc.policy),
                sc.split_dim,
                sc.steals(),
                sc.imbalance(),
            );
            for (i, d) in sc.devices.iter().enumerate() {
                let _ = write!(
                    out,
                    "{}{{\"name\": \"{}\", \"groups\": {}, \"chunks\": {}, \"steals\": {}, \"busy_ns\": {}}}",
                    if i > 0 { ", " } else { "" },
                    escape(&d.name),
                    d.groups,
                    d.chunks,
                    d.steals,
                    d.busy_ns,
                );
            }
            out.push_str("]},\n");
        }
        None => out.push_str("  \"sched\": null,\n"),
    }
    out.push_str("  \"counters\": {");
    let snap = poclrs::trace::metrics::global().snapshot();
    for (i, (k, v)) in snap.iter().enumerate() {
        let _ = write!(out, "{}\"{}\": {}", if i > 0 { ", " } else { "" }, escape(k), v);
    }
    out.push_str("},\n  \"phases\": [\n");
    let phases = poclrs::trace::metrics::phase_totals(events);
    for (i, p) in phases.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"cat\": \"{}\", \"name\": \"{}\", \"count\": {}, \"total_ns\": {}, \"max_ns\": {}}}{}\n",
            escape(p.cat),
            escape(&p.name),
            p.count,
            p.total_ns,
            p.max_ns,
            if i + 1 < phases.len() { "," } else { "" },
        );
    }
    out.push_str("  ]\n}\n");
    out
}
