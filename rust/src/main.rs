//! pocl-rs CLI: device discovery, kernel compilation inspection, and
//! suite runs.
//!
//! ```text
//! poclrs devices                      # Table 1 capability table
//! poclrs run <App> [device] [--stats] # run + verify one suite app
//! poclrs compile <file.cl> [LX]       # show compile stats + IR for a kernel
//! poclrs suite [device]               # run + verify the whole suite
//! ```
//!
//! `--stats` prints the uniformity/divergence compile counters and the
//! engine dispatch counters (gangs, diverged, vectorised/uniform/per-lane
//! instruction dispatches) for the run.

use std::sync::Arc;

use poclrs::cl::Platform;
use poclrs::kcc::{compile_workgroup, CompileOptions};
use poclrs::suite::{all_apps, app_by_name, runner, SizeClass};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let platform = Platform::default_platform();
    match args.first().map(|s| s.as_str()) {
        Some("devices") => {
            println!("platform `{}`\n{}", platform.name, platform.capability_table());
        }
        Some("run") => {
            let mut rest: Vec<&str> = args[1..].iter().map(|s| s.as_str()).collect();
            let want_stats = if let Some(i) = rest.iter().position(|a| *a == "--stats") {
                rest.remove(i);
                true
            } else {
                false
            };
            let name = *rest
                .first()
                .ok_or_else(|| String::from("usage: run <App> [device] [--stats]"))?;
            let dev = rest.get(1).copied().unwrap_or("pthread-gang(8)");
            let device = platform.find_device(dev)?;
            let app = app_by_name(name, SizeClass::Bench)
                .ok_or_else(|| format!("no app named `{name}`"))?;
            let r = runner::run_and_verify(&app, device.clone())?;
            println!(
                "{name}: OK on {dev} ({} work-groups, {:?} kernel time)",
                r.stats.workgroups, r.kernel_time
            );
            if want_stats {
                // Compile-side counters: one line per kernel launch pass,
                // at the pass's enqueue-time local size.
                let module = poclrs::frontend::compile(app.source)?;
                let opts = device.compile_options();
                for pass in &app.passes {
                    let Some(k) = module.kernel(pass.kernel) else { continue };
                    let wgf = compile_workgroup(k, pass.local, &opts)?;
                    println!(
                        "compile `{}` @ {:?}: regions={} uniform slots={} uniform regs={} divergent regions={}",
                        pass.kernel,
                        pass.local,
                        wgf.stats.regions,
                        wgf.stats.uniform_slots,
                        wgf.stats.uniform_regs,
                        wgf.stats.divergent_regions,
                    );
                }
                // Engine-side counters for the whole run.
                let s = &r.stats;
                println!(
                    "exec: workgroups={} gangs={} diverged={} dispatches={} (vectorised={} uniform={} per-lane={})",
                    s.workgroups,
                    s.gangs,
                    s.diverged_gangs,
                    s.dispatches(),
                    s.vector_insts,
                    s.uniform_insts,
                    s.lane_insts,
                );
            }
        }
        Some("suite") => {
            let dev = args.get(1).map(|s| s.as_str()).unwrap_or("pthread-gang(8)");
            let device = platform.find_device(dev)?;
            for app in all_apps(SizeClass::Small) {
                match runner::run_and_verify(&app, Arc::clone(&device)) {
                    Ok(r) => println!("{:<22} OK   {:>8.2?}", app.name, r.kernel_time),
                    Err(e) => println!("{:<22} FAIL {e}", app.name),
                }
            }
        }
        Some("compile") => {
            let path =
                args.get(1).ok_or_else(|| String::from("usage: compile <file.cl> [LX]"))?;
            let lx: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8);
            let src = std::fs::read_to_string(path)?;
            let module = poclrs::frontend::compile(&src)?;
            for k in &module.kernels {
                let wgf = compile_workgroup(k, [lx, 1, 1], &CompileOptions::default())?;
                println!("kernel `{}` @ local [{lx},1,1]: {:?}\n", k.name, wgf.stats);
                println!("--- region form ---\n{}", poclrs::ir::print::print_function(&wgf.reg_fn));
                println!("--- WI-loop form ---\n{}", poclrs::ir::print::print_function(&wgf.loop_fn));
            }
        }
        _ => {
            eprintln!("usage: poclrs devices | run <App> [device] | suite [device] | compile <file.cl> [LX]");
        }
    }
    Ok(())
}
