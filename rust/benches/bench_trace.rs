//! Tracer overhead — median suite-app wall-clock with the tracer
//! disabled vs enabled, emitting a `BENCH_trace.json` snapshot (the
//! ISSUE 10 criterion: the disabled tracer costs one relaxed atomic
//! load per emit point, so the `off` column *is* the product path and
//! the `on`/`off` ratio bounds what full collection adds).
//!
//! Run with `cargo bench --bench bench_trace`; `POCLRS_BENCH_MS` bounds
//! the per-case sampling budget (default 300 ms).

use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Duration;

use poclrs::bench::bench_fn;
use poclrs::devices::{basic::BasicDevice, Device, EngineKind};
use poclrs::suite::{app_by_name, runner, SizeClass};
use poclrs::trace;

struct Row {
    name: &'static str,
    off_ms: f64,
    on_ms: f64,
    events: usize,
}

fn main() {
    let budget = Duration::from_millis(
        std::env::var("POCLRS_BENCH_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(300),
    );
    let apps = ["MatrixMultiplication", "BlackScholes"];
    let device: Arc<dyn Device> = Arc::new(BasicDevice::new(EngineKind::GangVector(8)));

    println!("== Tracer overhead (gang-vector8) ==\n");
    let mut rows: Vec<Row> = Vec::new();
    for name in apps {
        let Some(app) = app_by_name(name, SizeClass::Bench) else {
            continue;
        };
        if let Err(e) = runner::run_and_verify(&app, device.clone()) {
            println!("{name:<22} FAILED {e}");
            continue;
        }
        trace::set_enabled(false);
        let _ = trace::take_events();
        let off = bench_fn(format!("{name}/trace-off"), 1, 15, budget, || {
            let _ = runner::run_on_device(&app, device.clone()).unwrap();
        });
        trace::set_enabled(true);
        let _ = trace::take_events();
        let on = bench_fn(format!("{name}/trace-on"), 1, 15, budget, || {
            let _ = runner::run_on_device(&app, device.clone()).unwrap();
            // Draining per iteration bounds buffer growth and charges the
            // drain to the traced configuration, where it belongs.
            let _ = trace::take_events();
        });
        // One more traced run for the per-run event census.
        let _ = runner::run_on_device(&app, device.clone()).unwrap();
        let events = trace::take_events().len();
        trace::set_enabled(false);
        println!(
            "{name:<22} off={:>8.2}ms  on={:>8.2}ms  overhead={:.3}x  events/run={events}",
            off.ms(),
            on.ms(),
            on.ms() / off.ms(),
        );
        rows.push(Row { name, off_ms: off.ms(), on_ms: on.ms(), events });
    }

    let mut json = String::from("{\n  \"bench\": \"trace\",\n  \"apps\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            json,
            "    {{\"name\": \"{}\", \"off_ms\": {:.4}, \"on_ms\": {:.4}, \"overhead\": {:.4}, \"events_per_run\": {}}}{}\n",
            r.name,
            r.off_ms,
            r.on_ms,
            r.on_ms / r.off_ms,
            r.events,
            if i + 1 < rows.len() { "," } else { "" },
        );
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_trace.json", &json) {
        Ok(()) => println!("\nsnapshot written to BENCH_trace.json"),
        Err(e) => println!("\ncould not write BENCH_trace.json: {e}"),
    }
    println!(
        "(expectation: the disabled path is the product path — one relaxed\n atomic load per emit point — and full collection stays within a few\n percent on these workloads; the Chrome export itself is off the\n measured path, it only runs at drain time)"
    );
}
