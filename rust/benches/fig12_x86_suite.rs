//! Fig. 12 — Intel x86-64 suite comparison.
//!
//! Paper: pocl vs the AMD and Intel proprietary OpenCL implementations on
//! a Core i7 (AVX2, 4 cores × 2 threads). Here: the handwritten-Rust
//! native baseline is the vendor stand-in; pocl-rs runs with the gang
//! engine at width 8 (AVX2 model) over all cores; `fiber` and `serial`
//! show what the kernel compiler's static parallelisation buys
//! (DESIGN.md §Substitutions explains the mapping).

use std::sync::Arc;

use poclrs::bench::figures::run_suite_figure;
use poclrs::devices::{basic::BasicDevice, threaded::ThreadedDevice, Device, EngineKind};

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let configs: Vec<(&str, Arc<dyn Device>)> = vec![
        ("pocl-gang8", Arc::new(ThreadedDevice::new(EngineKind::Gang(8), cores))),
        ("pocl-serial", Arc::new(BasicDevice::new(EngineKind::Serial))),
        ("fiber", Arc::new(BasicDevice::new(EngineKind::Fiber))),
    ];
    run_suite_figure("Fig. 12 analog: x86-64 (AVX2 model, gang x8)", &configs);
}
