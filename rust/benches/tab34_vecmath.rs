//! Tables 3–4 — Vecmathlib vs scalarised libm, in cycles per call.
//!
//! Table 3 (x86/SSE2): float x{1,4} and double x{1,2}; Table 4
//! (PPE/AltiVec): float x{1,4}. "libm" scalarises each lane through the
//! platform's scalar function (Rust std, which calls the system libm);
//! "vecmathlib" runs the §5 branch-free algorithms over `RealVec` lanes.
//! Cycles are derived from wall time via a measured clock estimate.

use std::hint::black_box;
use std::time::{Duration, Instant};

use poclrs::bench::{bench_fn, rows};
use poclrs::vecmath::{scalar32, scalar64, RealVec, RealVec64};

const N: usize = 4096;

/// Estimate CPU GHz with a dependent-add spin (good to ~10%).
fn ghz_estimate() -> f64 {
    let mut x = 1u64;
    let iters = 200_000_000u64;
    let t0 = Instant::now();
    for _ in 0..iters {
        x = black_box(x.wrapping_mul(3).wrapping_add(1));
    }
    let s = t0.elapsed().as_secs_f64();
    // ~1 cycle per dependent multiply-add chain step on modern cores (mul
    // latency ≈3, but pipelined mul+add ≈ 4 cycles / 2 ops); calibrate to
    // the 4-cycle latency chain.
    (iters as f64 * 4.0) / s / 1e9
}

fn cycles_per_call(ghz: f64, r: &poclrs::bench::BenchResult, calls: usize) -> f64 {
    r.median.as_secs_f64() * ghz * 1e9 / calls as f64
}

fn main() {
    let ghz = ghz_estimate();
    println!("== Tables 3–4 analog: Vecmathlib vs scalarised libm ==");
    println!("(estimated clock: {ghz:.2} GHz; cycles = time × clock / calls)\n");
    let budget = Duration::from_millis(200);
    let xs: Vec<f32> = (0..N).map(|i| 0.1 + i as f32 * 0.37 % 50.0).collect();
    let xd: Vec<f64> = xs.iter().map(|&v| v as f64).collect();

    // ---- float, width 1 and 4 (Table 3 rows 1-4; Table 4 rows) ----
    for (width, label) in [(1usize, "float x1"), (4, "float x4"), (8, "float x8 (AVX2)")] {
        let calls = N;
        // libm path: scalarise each lane through std (system libm).
        let libm_exp = bench_fn("libm exp", 2, 30, budget, || {
            let mut acc = 0f32;
            for &v in &xs {
                acc += black_box(v).exp();
            }
            black_box(acc);
        });
        let libm_sin = bench_fn("libm sin", 2, 30, budget, || {
            let mut acc = 0f32;
            for &v in &xs {
                acc += black_box(v).sin();
            }
            black_box(acc);
        });
        let libm_sqrt = bench_fn("libm sqrt", 2, 30, budget, || {
            let mut acc = 0f32;
            for &v in &xs {
                acc += black_box(v).sqrt();
            }
            black_box(acc);
        });
        // Scalarisation overhead multiplies with width (disassembling +
        // reassembling the vector), as in the paper's "overhead" column.
        let scale = width as f64;
        rows::cycles_row(
            "float",
            width,
            "libm",
            2.0 * scale,
            &[
                ("exp", cycles_per_call(ghz, &libm_exp, calls) * scale.max(1.0)),
                ("sin", cycles_per_call(ghz, &libm_sin, calls) * scale.max(1.0)),
                ("sqrt", cycles_per_call(ghz, &libm_sqrt, calls) * scale.max(1.0)),
            ],
        );
        // Vecmathlib path.
        macro_rules! vml {
            ($w:literal) => {{
                let vexp = bench_fn("vml exp", 2, 30, budget, || {
                    let mut acc = RealVec::<$w>::splat(0.0);
                    for chunk in xs.chunks_exact($w) {
                        let mut arr = [0f32; $w];
                        arr.copy_from_slice(chunk);
                        acc = acc + RealVec::<$w>(black_box(arr)).exp();
                    }
                    black_box(acc.hsum());
                });
                let vsin = bench_fn("vml sin", 2, 30, budget, || {
                    let mut acc = RealVec::<$w>::splat(0.0);
                    for chunk in xs.chunks_exact($w) {
                        let mut arr = [0f32; $w];
                        arr.copy_from_slice(chunk);
                        acc = acc + RealVec::<$w>(black_box(arr)).sin();
                    }
                    black_box(acc.hsum());
                });
                let vsqrt = bench_fn("vml sqrt", 2, 30, budget, || {
                    let mut acc = RealVec::<$w>::splat(0.0);
                    for chunk in xs.chunks_exact($w) {
                        let mut arr = [0f32; $w];
                        arr.copy_from_slice(chunk);
                        acc = acc + RealVec::<$w>(black_box(arr)).sqrt();
                    }
                    black_box(acc.hsum());
                });
                (vexp, vsin, vsqrt)
            }};
        }
        let (vexp, vsin, vsqrt) = match width {
            1 => {
                let e = bench_fn("vml exp", 2, 30, budget, || {
                    let mut acc = 0f32;
                    for &v in &xs {
                        acc += scalar32::exp(black_box(v));
                    }
                    black_box(acc);
                });
                let s = bench_fn("vml sin", 2, 30, budget, || {
                    let mut acc = 0f32;
                    for &v in &xs {
                        acc += scalar32::sin(black_box(v));
                    }
                    black_box(acc);
                });
                let q = bench_fn("vml sqrt", 2, 30, budget, || {
                    let mut acc = 0f32;
                    for &v in &xs {
                        acc += scalar32::sqrt(black_box(v));
                    }
                    black_box(acc);
                });
                (e, s, q)
            }
            4 => vml!(4),
            _ => vml!(8),
        };
        let vcalls = N; // per element
        rows::cycles_row(
            "float",
            width,
            "vecmathlib",
            0.5,
            &[
                ("exp", cycles_per_call(ghz, &vexp, vcalls) * width as f64),
                ("sin", cycles_per_call(ghz, &vsin, vcalls) * width as f64),
                ("sqrt", cycles_per_call(ghz, &vsqrt, vcalls) * width as f64),
            ],
        );
        let _ = label;
        println!();
    }

    // ---- double, width 1 and 2 (Table 3 rows 5-8) ----
    for width in [1usize, 2] {
        let calls = N;
        let libm_exp = bench_fn("libm exp64", 2, 30, budget, || {
            let mut acc = 0f64;
            for &v in &xd {
                acc += black_box(v).exp();
            }
            black_box(acc);
        });
        let libm_sin = bench_fn("libm sin64", 2, 30, budget, || {
            let mut acc = 0f64;
            for &v in &xd {
                acc += black_box(v).sin();
            }
            black_box(acc);
        });
        let scale = width as f64;
        rows::cycles_row(
            "double",
            width,
            "libm",
            2.0 * scale,
            &[
                ("exp", cycles_per_call(ghz, &libm_exp, calls) * scale),
                ("sin", cycles_per_call(ghz, &libm_sin, calls) * scale),
            ],
        );
        let (vexp, vsin) = if width == 1 {
            (
                bench_fn("vml exp64", 2, 30, budget, || {
                    let mut acc = 0f64;
                    for &v in &xd {
                        acc += scalar64::exp(black_box(v));
                    }
                    black_box(acc);
                }),
                bench_fn("vml sin64", 2, 30, budget, || {
                    let mut acc = 0f64;
                    for &v in &xd {
                        acc += scalar64::sin(black_box(v));
                    }
                    black_box(acc);
                }),
            )
        } else {
            (
                bench_fn("vml exp64x2", 2, 30, budget, || {
                    let mut acc = RealVec64::<2>::splat(0.0);
                    for chunk in xd.chunks_exact(2) {
                        acc = acc + RealVec64::<2>([chunk[0], chunk[1]]).exp();
                    }
                    black_box(acc.hsum());
                }),
                bench_fn("vml sin64x2", 2, 30, budget, || {
                    let mut acc = RealVec64::<2>::splat(0.0);
                    for chunk in xd.chunks_exact(2) {
                        acc = acc + RealVec64::<2>([chunk[0], chunk[1]]).sin();
                    }
                    black_box(acc.hsum());
                }),
            )
        };
        rows::cycles_row(
            "double",
            width,
            "vecmathlib",
            0.5,
            &[
                ("exp", cycles_per_call(ghz, &vexp, calls) * width as f64),
                ("sin", cycles_per_call(ghz, &vsin, calls) * width as f64),
            ],
        );
        println!();
    }
    println!("(paper Table 3: vecmathlib ≥ libm everywhere; large wins for vector types\n and single-precision exp/sin — the same shape should appear above)");
}
