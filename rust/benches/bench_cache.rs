//! Cold vs warm kernel-compilation cost through the persistent cache,
//! emitting a `BENCH_cache.json` snapshot (the ISSUE 5 criterion: warm
//! build time < 20% of cold).
//!
//! Three build paths are timed per app, specialising every pass kernel
//! at several local sizes (the repeat-traffic shape the cache targets):
//!
//! * `cold`        — frontend + `compile_workgroup` for every
//!                   specialisation, empty cache directory.
//! * `warm`        — fresh `Program` from the same source against the
//!                   now-populated directory: frontend still runs, every
//!                   specialisation is a disk hit (decode, no compile).
//! * `from_binary` — `Program::from_binary` of the exported program
//!                   binary: no frontend, no compile, pure decode.
//!
//! Run with `cargo bench --bench bench_cache`. Uses a private temp
//! directory; the user-level default cache is never touched.

use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Instant;

use poclrs::cache::DiskCache;
use poclrs::cl::Program;
use poclrs::kcc::CompileOptions;
use poclrs::suite::{app_by_name, SizeClass};

const ITERS: usize = 5;
const LOCAL_XS: [usize; 4] = [4, 8, 16, 32];

/// Specialise every pass kernel at each bench local size.
fn specialize(program: &Program, app: &poclrs::suite::App) {
    let opts = CompileOptions::default();
    for pass in &app.passes {
        for lx in LOCAL_XS {
            let local = [lx, pass.local[1], pass.local[2]];
            program
                .workgroup_function(pass.kernel, local, &opts)
                .expect("specialisation failed");
        }
    }
}

fn time_ms(f: impl FnOnce()) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64() * 1e3
}

fn main() {
    let dir = std::env::temp_dir().join(format!("poclrs-bench-cache-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let apps = ["DCT", "BinomialOption", "NBody", "BitonicSort"];

    println!("== Persistent kernel cache: cold vs warm program builds ==\n");
    let mut json = String::from("{\n  \"bench\": \"cache\",\n  \"apps\": [\n");
    let mut first = true;
    let mut worst_ratio: f64 = 0.0;
    for name in apps {
        let Some(app) = app_by_name(name, SizeClass::Small) else {
            println!("{name:<18} SKIP (unknown app)");
            continue;
        };
        let disk = Arc::new(DiskCache::at(&dir).expect("cache dir"));
        let specs = app.passes.len() * LOCAL_XS.len();

        // Cold: clear the directory every iteration so each build pays
        // the full frontend + kernel-compiler cost.
        let mut cold = f64::MAX;
        for _ in 0..ITERS {
            disk.clear().expect("clear");
            cold = cold.min(time_ms(|| {
                let p = Program::build_cached(app.source, Some(disk.clone())).unwrap();
                specialize(&p, &app);
            }));
        }

        // Warm: the directory now holds every specialisation; a fresh
        // program (same source) must hit disk for all of them.
        let mut warm = f64::MAX;
        for _ in 0..ITERS {
            let mut misses = 0;
            warm = warm.min(time_ms(|| {
                let p = Program::build_cached(app.source, Some(disk.clone())).unwrap();
                specialize(&p, &app);
                misses = p.cache_stats().misses;
            }));
            assert_eq!(misses, 0, "{name}: warm build must not compile");
        }

        // Program-binary path: skip the frontend entirely.
        let exporter = Program::build_cached(app.source, Some(disk.clone())).unwrap();
        specialize(&exporter, &app);
        let bytes = exporter.binaries();
        let mut from_binary = f64::MAX;
        for _ in 0..ITERS {
            from_binary = from_binary.min(time_ms(|| {
                let p = Program::from_binary(&bytes).unwrap();
                specialize(&p, &app);
                assert_eq!(p.cache_stats().misses, 0);
            }));
        }

        let ratio = warm / cold;
        worst_ratio = worst_ratio.max(ratio);
        println!(
            "{name:<18} specs={specs:<3} cold={cold:8.3}ms  warm={warm:8.3}ms ({:5.1}% of cold)  from_binary={from_binary:8.3}ms",
            ratio * 100.0
        );
        if !first {
            let _ = writeln!(json, ",");
        }
        first = false;
        let _ = write!(
            json,
            "    {{\"name\": \"{name}\", \"specializations\": {specs}, \"cold_ms\": {cold:.4}, \"warm_ms\": {warm:.4}, \"warm_ratio\": {ratio:.4}, \"from_binary_ms\": {from_binary:.4}, \"binary_bytes\": {}}}",
            bytes.len()
        );
    }
    let _ = writeln!(json, "\n  ],\n  \"worst_warm_ratio\": {worst_ratio:.4}\n}}");
    match std::fs::write("BENCH_cache.json", &json) {
        Ok(()) => println!("\nsnapshot written to BENCH_cache.json"),
        Err(e) => println!("\ncould not write BENCH_cache.json: {e}"),
    }
    println!(
        "(expectation: warm < 20% of cold on every row — deserialising a poclbin\n entry skips the whole §4 pass pipeline; from_binary also skips the frontend)"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
