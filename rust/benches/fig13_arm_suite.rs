//! Fig. 13 — ARM Cortex-A9 suite comparison.
//!
//! Paper: pocl vs FreeOCL on a PandaBoard (2 cores, NEON). Here: gang
//! width 4 (NEON model) over 2 worker threads vs the fiber engine — the
//! same per-work-item-context architecture FreeOCL uses, on an identical
//! substrate, so the pocl/fiber ratio is the controlled version of the
//! paper's comparison.

use std::sync::Arc;

use poclrs::bench::figures::run_suite_figure;
use poclrs::devices::{basic::BasicDevice, threaded::ThreadedDevice, Device, EngineKind};

fn main() {
    let configs: Vec<(&str, Arc<dyn Device>)> = vec![
        ("pocl-gang4x2", Arc::new(ThreadedDevice::new(EngineKind::Gang(4), 2))),
        ("freeocl-fiber", Arc::new(BasicDevice::new(EngineKind::Fiber))),
    ];
    run_suite_figure("Fig. 13 analog: ARM Cortex-A9 (NEON model, gang x4, 2 threads)", &configs);
}
