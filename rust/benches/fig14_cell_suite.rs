//! Fig. 14 — STI Cell BE / PowerPC Processing Element comparison.
//!
//! Paper: pocl vs the IBM OpenCL Development Kit on a PS3's PPE (2
//! hardware threads, AltiVec), CPU device only. Here: gang width 4
//! (AltiVec model) over 2 threads vs serial and fiber configurations.

use std::sync::Arc;

use poclrs::bench::figures::run_suite_figure;
use poclrs::devices::{basic::BasicDevice, threaded::ThreadedDevice, Device, EngineKind};

fn main() {
    let configs: Vec<(&str, Arc<dyn Device>)> = vec![
        ("pocl-gang4x2", Arc::new(ThreadedDevice::new(EngineKind::Gang(4), 2))),
        ("ibm-serial", Arc::new(BasicDevice::new(EngineKind::Serial))),
        ("fiber", Arc::new(BasicDevice::new(EngineKind::Fiber))),
    ];
    run_suite_figure("Fig. 14 analog: Cell PPE (AltiVec model, gang x4, 2 threads)", &configs);
}
