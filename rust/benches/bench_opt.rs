//! Optimizer impact — O0 vs O2 per suite app per engine, emitting a
//! `BENCH_opt.json` snapshot (the ISSUE 6 criterion: O2 cuts interpreter
//! dispatches by ≥20% on at least half the suite apps, and the dispatch
//! reduction shows up as wall-clock on every engine).
//!
//! Run with `cargo bench --bench bench_opt`; `POCLRS_BENCH_MS` bounds the
//! per-case sampling budget (default 300 ms).

use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Duration;

use poclrs::bench::{bench_fn, BenchResult};
use poclrs::devices::{basic::BasicDevice, Device, EngineKind, LaunchStats};
use poclrs::kcc::OptLevel;
use poclrs::suite::{all_apps, runner, SizeClass};

const WIDTH: usize = 8;

/// One (level, timing, launch counters) measurement cell.
type Cell = (OptLevel, BenchResult, LaunchStats);

fn main() {
    let budget = Duration::from_millis(
        std::env::var("POCLRS_BENCH_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(300),
    );
    let engines: Vec<(&str, EngineKind)> = vec![
        ("serial", EngineKind::Serial),
        ("gang-scalar8", EngineKind::Gang(WIDTH)),
        ("gang-vector8", EngineKind::GangVector(WIDTH)),
        ("bytecode8", EngineKind::Bytecode(WIDTH)),
    ];

    println!("== Optimizer impact: O0 vs O2, per app, per engine (width {WIDTH}) ==\n");
    let mut json = String::from("{\n");
    let _ = writeln!(json, "  \"bench\": \"opt\",\n  \"width\": {WIDTH},\n  \"apps\": [");
    let mut first_app = true;
    for app in all_apps(SizeClass::Bench) {
        let name = app.name;
        let mut rows: Vec<(&str, Cell, Cell)> = Vec::new();
        for (label, engine) in &engines {
            let mut cells: Vec<Cell> = Vec::new();
            for level in [OptLevel::O0, OptLevel::O2] {
                // Pin the level on the device (not via POCLRS_OPT) so the
                // two runs are isolated and their cache keys distinct.
                let device: Arc<dyn Device> =
                    Arc::new(BasicDevice::with_opt_level(*engine, level));
                match runner::run_and_verify(&app, device.clone()) {
                    Ok(r) => {
                        let bench = bench_fn(
                            format!("{name}/{label}/O{}", level.as_u32()),
                            1,
                            15,
                            budget,
                            || {
                                let _ = runner::run_on_device(&app, device.clone()).unwrap();
                            },
                        );
                        cells.push((level, bench, r.stats));
                    }
                    Err(e) => println!("{name:<22} {label} O{}: FAILED {e}", level.as_u32()),
                }
            }
            if let [o0, o2] = cells.as_slice() {
                rows.push((*label, o0.clone(), o2.clone()));
            }
        }
        if rows.is_empty() {
            continue;
        }
        let cells: Vec<String> = rows
            .iter()
            .map(|(l, o0, o2)| {
                let disp0 = o0.2.dispatches().max(1);
                format!(
                    "{l}: {:.2}ms -> {:.2}ms ({:.2}x, dispatches -{:.0}%)",
                    o0.1.ms(),
                    o2.1.ms(),
                    o0.1.ms() / o2.1.ms(),
                    100.0 * (1.0 - o2.2.dispatches() as f64 / disp0 as f64),
                )
            })
            .collect();
        println!("{name:<22} {}", cells.join("  "));

        if !first_app {
            let _ = writeln!(json, ",");
        }
        first_app = false;
        let _ = write!(json, "    {{\"name\": \"{name}\", \"engines\": [");
        for (i, (label, o0, o2)) in rows.iter().enumerate() {
            if i > 0 {
                let _ = write!(json, ", ");
            }
            let _ = write!(
                json,
                "{{\"engine\": \"{label}\", \
                 \"o0\": {{\"ms\": {:.4}, \"dispatches\": {}}}, \
                 \"o2\": {{\"ms\": {:.4}, \"dispatches\": {}}}, \
                 \"dispatch_reduction\": {:.4}}}",
                o0.1.ms(),
                o0.2.dispatches(),
                o2.1.ms(),
                o2.2.dispatches(),
                1.0 - o2.2.dispatches() as f64 / o0.2.dispatches().max(1) as f64,
            );
        }
        let _ = write!(json, "]}}");
    }
    let _ = writeln!(json, "\n  ]\n}}");
    match std::fs::write("BENCH_opt.json", &json) {
        Ok(()) => println!("\nsnapshot written to BENCH_opt.json"),
        Err(e) => println!("\ncould not write BENCH_opt.json: {e}"),
    }
    println!(
        "(expectation: dispatches drop >=20% on at least half the apps —\n the tests/opt_verify.rs acceptance criterion — and O2 never loses)"
    );
}
