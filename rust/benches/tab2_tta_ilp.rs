//! Table 2 + §6.4 — static multi-issue ILP on the TTA simulator.
//!
//! Runs the unmodified DCT workload on the Table 2 datapath (4 int ALUs,
//! 4 FADD, 4 FMUL, 9 LSUs) with and without the horizontal inner-loop
//! parallelisation pass, reporting cycle counts scaled to 100 MHz.
//! Paper: 53.5 ms → 10.2 ms (≈5.2×).

use std::sync::Arc;

use poclrs::devices::ttasim::TtaSimDevice;
use poclrs::devices::Device;
use poclrs::suite::{apps::dct, runner, SizeClass};

fn main() {
    println!("== Table 2 / §6.4 analog: TTA static multi-issue, DCT ==");
    println!("datapath: 4 int ALU, 4 FADD, 4 FMUL, 9 LSU (Table 2)\n");
    let app = dct::build(SizeClass::Bench);
    let mut cycles = Vec::new();
    for horizontal in [false, true] {
        let device = Arc::new(TtaSimDevice::new(horizontal));
        let r = runner::run_and_verify(&app, device.clone() as Arc<dyn Device>)
            .expect("DCT verifies on ttasim");
        println!(
            "horizontal={horizontal:<5}  cycles={:>12}  time@100MHz={:>8.2} ms",
            r.stats.cycles,
            device.cycles_to_ms(r.stats.cycles)
        );
        cycles.push(r.stats.cycles);
    }
    println!(
        "\nILP speedup: {:.2}x   (paper: 53.5 ms / 10.2 ms = 5.25x)",
        cycles[0] as f64 / cycles[1] as f64
    );
}
