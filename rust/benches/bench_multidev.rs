//! Heterogeneous multi-device scheduler — co-execution of one NDRange
//! across a device group, emitting a `BENCH_multidev.json` snapshot
//! (the ISSUE 9 criteria: wall-clock improves from 1 to N members on a
//! homogeneous group, and on an asymmetric serial+vector+bytecode mix
//! the dynamic self-scheduler beats the worst static split).
//!
//! Run with `cargo bench --bench bench_multidev`; `POCLRS_BENCH_MS`
//! bounds the per-case sampling budget (default 300 ms).

use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Duration;

use poclrs::bench::bench_fn;
use poclrs::devices::{basic::BasicDevice, Device, EngineKind};
use poclrs::sched::{DeviceGroup, Dynamic, SchedPolicy, SchedStats, StaticSplit};
use poclrs::suite::{app_by_name, runner, SizeClass};

const WIDTH: usize = 8;

fn group(name: &str, engines: &[EngineKind], policy: Arc<dyn SchedPolicy>) -> Arc<dyn Device> {
    let members: Vec<Arc<dyn Device>> = engines
        .iter()
        .map(|&e| Arc::new(BasicDevice::new(e)) as Arc<dyn Device>)
        .collect();
    Arc::new(DeviceGroup::new(name, members, policy).expect("valid group"))
}

/// One measured configuration: median wall-clock plus the scheduler
/// breakdown of a verification run.
struct Row {
    label: String,
    ms: f64,
    sched: Option<SchedStats>,
}

fn measure(
    app_name: &str,
    label: &str,
    device: Arc<dyn Device>,
    budget: Duration,
) -> Option<Row> {
    let app = app_by_name(app_name, SizeClass::Bench)?;
    match runner::run_and_verify(&app, device.clone()) {
        Ok(r) => {
            let bench = bench_fn(format!("{app_name}/{label}"), 1, 15, budget, || {
                let _ = runner::run_on_device(&app, device.clone()).unwrap();
            });
            Some(Row { label: label.to_string(), ms: bench.ms(), sched: r.sched })
        }
        Err(e) => {
            println!("{app_name:<22} {label}: FAILED {e}");
            None
        }
    }
}

fn json_row(json: &mut String, row: &Row, first: bool) {
    if !first {
        let _ = write!(json, ", ");
    }
    let _ = write!(json, "{{\"config\": \"{}\", \"ms\": {:.4}", row.label, row.ms);
    if let Some(sc) = &row.sched {
        let groups: Vec<String> =
            sc.devices.iter().map(|d| d.groups.to_string()).collect();
        let _ = write!(
            json,
            ", \"steals\": {}, \"imbalance\": {:.3}, \"groups\": [{}]",
            sc.steals(),
            sc.imbalance(),
            groups.join(", ")
        );
    }
    let _ = write!(json, "}}");
}

fn main() {
    let budget = Duration::from_millis(
        std::env::var("POCLRS_BENCH_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(300),
    );
    // The asymmetric mix: a deliberately slow serial member next to the
    // two fast tiers — the shape the dynamic self-scheduler exists for.
    let mix = [EngineKind::Serial, EngineKind::GangVector(WIDTH), EngineKind::Bytecode(WIDTH)];
    let apps = ["MatrixMultiplication", "BlackScholes"];

    println!("== Heterogeneous device-group scheduler (width {WIDTH}) ==\n");
    let mut json = String::from("{\n");
    let _ = writeln!(json, "  \"bench\": \"multidev\",\n  \"width\": {WIDTH},\n  \"apps\": [");
    let mut first_app = true;
    for name in apps {
        // 1 -> N scaling on a homogeneous vector-gang group.
        let mut scaling: Vec<Row> = Vec::new();
        for members in 1..=3usize {
            let engines = vec![EngineKind::GangVector(WIDTH); members];
            let dev = group("scale", &engines, Arc::new(Dynamic::new()));
            if let Some(row) = measure(name, &format!("gang-vector8 x{members}"), dev, budget) {
                scaling.push(row);
            }
        }
        if let Some(base) = scaling.first().map(|r| r.ms) {
            let cells: Vec<String> = scaling
                .iter()
                .map(|r| format!("{}={:.2}ms ({:.2}x)", r.label, r.ms, base / r.ms))
                .collect();
            println!("{name:<22} scaling: {}", cells.join("  "));
        }

        // Policy shoot-out on the asymmetric mix. static-skew pins most
        // of the range to the serial member — the deliberately bad split
        // the dynamic scheduler must beat.
        let policies: Vec<(&str, Arc<dyn SchedPolicy>)> = vec![
            ("static-even", Arc::new(StaticSplit::even())),
            ("static-skew", Arc::new(StaticSplit::new(vec![4.0, 1.0, 1.0]))),
            ("static-profiled", Arc::new(StaticSplit::new(vec![1.0, 8.0, 8.0]))),
            ("dynamic", Arc::new(Dynamic::new())),
        ];
        let mut mix_rows: Vec<Row> = Vec::new();
        for (label, policy) in policies {
            let dev = group("mix", &mix, policy);
            if let Some(row) = measure(name, label, dev, budget) {
                mix_rows.push(row);
            }
        }
        for r in &mix_rows {
            let (steals, imb) = r
                .sched
                .as_ref()
                .map(|s| (s.steals(), s.imbalance()))
                .unwrap_or((0, 1.0));
            println!(
                "{name:<22} {:<16} {:>8.2}ms  steals={steals:<4} imbalance={imb:.2}",
                r.label, r.ms
            );
        }
        let dynamic_ms = mix_rows.iter().find(|r| r.label == "dynamic").map(|r| r.ms);
        let worst_static = mix_rows
            .iter()
            .filter(|r| r.label.starts_with("static"))
            .map(|r| r.ms)
            .fold(f64::MIN, f64::max);
        if let Some(d) = dynamic_ms {
            println!(
                "{name:<22} dynamic vs worst static: {:.2}x {}",
                worst_static / d,
                if d < worst_static { "(dynamic wins)" } else { "(UNEXPECTED)" }
            );
        }
        println!();

        if !first_app {
            let _ = writeln!(json, ",");
        }
        first_app = false;
        let _ = write!(json, "    {{\"name\": \"{name}\", \"scaling\": [");
        for (i, r) in scaling.iter().enumerate() {
            json_row(&mut json, r, i == 0);
        }
        let _ = write!(json, "], \"mix\": [");
        for (i, r) in mix_rows.iter().enumerate() {
            json_row(&mut json, r, i == 0);
        }
        let _ = write!(json, "]}}");
    }
    let _ = writeln!(json, "\n  ]\n}}");
    match std::fs::write("BENCH_multidev.json", &json) {
        Ok(()) => println!("snapshot written to BENCH_multidev.json"),
        Err(e) => println!("could not write BENCH_multidev.json: {e}"),
    }
    println!(
        "(expectation: the x2/x3 homogeneous rows beat x1 — co-execution\n scales with members — and on the asymmetric serial+vector+bytecode\n mix the dynamic self-scheduler's wall-clock beats the worst static\n split, with imbalance near 1.0 and a non-zero steal count)"
    );
}
