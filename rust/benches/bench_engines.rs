//! Engine comparison — serial vs per-lane gang vs lane-batched vector
//! gang vs the threaded-bytecode tier vs the template jit over
//! uniform-control suite kernels, emitting a `BENCH_engines.json`
//! snapshot (the ISSUE 2 wall-clock criterion: gang-vector beats
//! gang-scalar at width 8; the ISSUE 7 criterion: bytecode beats
//! gang-vector by ≥2× on MatrixMultiplication and BlackScholes; the
//! ISSUE 8 expectation: jit8 at or below bytecode8 on the covered
//! kernels — on non-x86-64 hosts the jit8 row degrades to bytecode).
//!
//! Run with `cargo bench --bench bench_engines`; `POCLRS_BENCH_MS` bounds
//! the per-case sampling budget (default 300 ms).

use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Duration;

use poclrs::bench::{bench_fn, BenchResult};
use poclrs::devices::{basic::BasicDevice, Device, EngineKind};
use poclrs::suite::{app_by_name, runner, SizeClass};

const WIDTH: usize = 8;

fn main() {
    let budget = Duration::from_millis(
        std::env::var("POCLRS_BENCH_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(300),
    );
    let engines: Vec<(&str, EngineKind)> = vec![
        ("serial", EngineKind::Serial),
        ("gang-scalar8", EngineKind::Gang(WIDTH)),
        ("gang-vector8", EngineKind::GangVector(WIDTH)),
        ("bytecode8", EngineKind::Bytecode(WIDTH)),
        ("jit8", EngineKind::Jit(WIDTH)),
    ];
    // Uniform-control float kernels: the vector engine's best case, and
    // the shape of the Fig. 12 suite wins the paper reports for SIMD.
    // BlackScholes is the second ISSUE 7 anchor (select-heavy, math-dense).
    let apps = ["SimpleConvolution", "DCT", "MatrixMultiplication", "BlackScholes"];

    println!(
        "== Engine matrix: serial vs gang-scalar vs gang-vector vs bytecode vs jit (width {WIDTH}) ==\n"
    );
    let mut json = String::from("{\n");
    let _ = writeln!(json, "  \"bench\": \"engines\",\n  \"width\": {WIDTH},\n  \"apps\": [");
    let mut first_app = true;
    for name in apps {
        let Some(app) = app_by_name(name, SizeClass::Bench) else {
            println!("{name:<22} SKIP (unknown app)");
            continue;
        };
        let mut results: Vec<(&str, BenchResult, poclrs::devices::LaunchStats)> = Vec::new();
        for (label, engine) in &engines {
            let device: Arc<dyn Device> = Arc::new(BasicDevice::new(*engine));
            match runner::run_and_verify(&app, device.clone()) {
                Ok(r) => {
                    let bench = bench_fn(format!("{name}/{label}"), 1, 15, budget, || {
                        let _ = runner::run_on_device(&app, device.clone()).unwrap();
                    });
                    results.push((*label, bench, r.stats));
                }
                Err(e) => println!("{name:<22} {label}: FAILED {e}"),
            }
        }
        if results.is_empty() {
            continue;
        }
        let base = results[0].1.ms();
        let cells: Vec<String> = results
            .iter()
            .map(|(l, r, _)| format!("{l}={:.2}ms ({:.2}x)", r.ms(), r.ms() / base))
            .collect();
        println!("{name:<22} {}", cells.join("  "));

        if !first_app {
            let _ = writeln!(json, ",");
        }
        first_app = false;
        let _ = write!(json, "    {{\"name\": \"{name}\", \"results\": [");
        for (i, (label, r, stats)) in results.iter().enumerate() {
            if i > 0 {
                let _ = write!(json, ", ");
            }
            let _ = write!(
                json,
                "{{\"engine\": \"{label}\", \"ms\": {:.4}, \"dispatches\": {}, \"gangs\": {}, \"diverged\": {}}}",
                r.ms(),
                stats.dispatches(),
                stats.gangs,
                stats.diverged_gangs
            );
        }
        let _ = write!(json, "]}}");
    }
    let _ = writeln!(json, "\n  ]\n}}");
    match std::fs::write("BENCH_engines.json", &json) {
        Ok(()) => println!("\nsnapshot written to BENCH_engines.json"),
        Err(e) => println!("\ncould not write BENCH_engines.json: {e}"),
    }
    println!(
        "(expectation: gang-vector8 < gang-scalar8 wall-clock on every row —\n the ~{WIDTH}x dispatch reduction shows up as real throughput —\n bytecode8 <= 0.5x gang-vector8 on MatrixMultiplication and BlackScholes,\n and jit8 <= bytecode8 wherever the templates cover the hot regions)"
    );
}
