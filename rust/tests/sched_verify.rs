//! Heterogeneous scheduler verification: partition-coverage properties
//! over the `ChunkSource` seam (every work-group handed out exactly
//! once, no matter the policy, member count, ratios, or interleave), plus
//! host-API integration tests that a split launch executes every group
//! exactly once, reports a consistent per-member breakdown, and composes
//! with user global offsets bit-identically to a single-device run.

use std::sync::Arc;

use poclrs::cl::{CommandQueue, Context, Kernel, KernelArg, Program, QueueProperties};
use poclrs::devices::{basic::BasicDevice, Device, EngineKind};
use poclrs::sched::{ChunkSource as _, DeviceGroup, Dynamic, SchedPolicy, StaticSplit};
use poclrs::suite::{all_apps, runner, SizeClass};
use poclrs::testing::check;

/// Property: for any total, member count, policy, polling interleave,
/// and reported throughput rates, draining a plan covers every
/// work-group index exactly once — the scheduler can never skip or
/// double-execute a group.
#[test]
fn any_partition_covers_every_group_exactly_once() {
    check(300, |rng| {
        let total = rng.range(1, 400);
        let members = rng.range(1, 6);
        let policy: Arc<dyn SchedPolicy> = match rng.below(4) {
            0 => {
                let ratios: Vec<f64> =
                    (0..members).map(|_| f64::from(rng.f32(0.0, 8.0))).collect();
                Arc::new(StaticSplit::new(ratios))
            }
            1 => Arc::new(StaticSplit::even()),
            2 => Arc::new(Dynamic::fixed(rng.range(1, 48))),
            _ => Arc::new(Dynamic::new()),
        };
        let src = policy.plan(total, members);
        let mut cover = vec![0usize; total];
        // Poll live members in a random interleave with random rates —
        // the scheduler must tile the range under any concurrency order.
        let mut live: Vec<usize> = (0..members).collect();
        while !live.is_empty() {
            let pick = rng.below(live.len());
            let dev = live[pick];
            let rate = f64::from(rng.f32(0.5, 500.0));
            match src.next(dev, rate) {
                Some(c) => {
                    assert!(c.len > 0, "empty chunk from {}", policy.name());
                    assert!(
                        c.start + c.len <= total,
                        "chunk [{}, {}) overruns total {} under {}",
                        c.start,
                        c.start + c.len,
                        total,
                        policy.name()
                    );
                    for slot in cover.iter_mut().skip(c.start).take(c.len) {
                        *slot += 1;
                    }
                }
                None => {
                    live.swap_remove(pick);
                }
            }
        }
        for (g, &n) in cover.iter().enumerate() {
            assert_eq!(
                n, 1,
                "group {g} covered {n} times (total={total}, members={members}, policy={})",
                policy.name()
            );
        }
    });
}

/// A group of basic devices over the given engines.
fn group_of(engines: &[EngineKind], policy: Arc<dyn SchedPolicy>) -> Arc<dyn Device> {
    let members: Vec<Arc<dyn Device>> = engines
        .iter()
        .map(|&e| Arc::new(BasicDevice::new(e)) as Arc<dyn Device>)
        .collect();
    Arc::new(DeviceGroup::new("group", members, policy).expect("valid group"))
}

fn policies() -> Vec<Arc<dyn SchedPolicy>> {
    vec![
        Arc::new(Dynamic::fixed(1)),
        Arc::new(Dynamic::new()),
        Arc::new(StaticSplit::new(vec![3.0, 1.0, 2.0])),
        Arc::new(StaticSplit::even()),
    ]
}

/// Integration: each work-group increments its own cell once, so any
/// skipped or doubly-executed group is visible in the output. The
/// per-member scheduler breakdown must account for every group.
#[test]
fn split_launch_executes_every_group_exactly_once() {
    const SRC: &str = "__kernel void tick(__global float *x) {
        x[get_group_id(0)] += 1.0f;
    }";
    let n = 64usize;
    for policy in policies() {
        let pname = policy.name();
        let device =
            group_of(&[EngineKind::Serial, EngineKind::Serial, EngineKind::Serial], policy);
        let ctx = Arc::new(Context::new(device));
        let q = CommandQueue::new(ctx.clone());
        let program = Program::build(SRC).unwrap();
        let buf = ctx.create_buffer(n * 4).unwrap();
        let up = q.enqueue_write_slice(buf, &vec![0.0f32; n], &[]).unwrap();
        let mut k = Kernel::new(&program, "tick").unwrap();
        k.set_arg(0, KernelArg::Buf(buf)).unwrap();
        let ev = q
            .enqueue_nd_range(&program, &k, [n, 1, 1], [1, 1, 1], &[up])
            .unwrap_or_else(|e| panic!("[{pname}] split launch failed: {e}"));
        let rd = q.enqueue_read_buffer(buf, 0, n * 4, &[ev]).unwrap();
        let out: Vec<f32> = rd.wait_vec().unwrap();
        for (g, &v) in out.iter().enumerate() {
            assert_eq!(v, 1.0, "[{pname}] group {g} executed {v} times");
        }
        let sched = ev
            .sched_stats()
            .unwrap_or_else(|| panic!("[{pname}] split launch must report scheduler stats"));
        assert_eq!(sched.devices.len(), 3, "[{pname}] member rows");
        assert_eq!(sched.groups(), n, "[{pname}] per-member groups sum to the launch");
        assert_eq!(sched.total().workgroups, n, "[{pname}] stats totals agree");
        let per: usize = sched.devices.iter().map(|d| d.stats.workgroups).sum();
        assert_eq!(per, n, "[{pname}] per-member launch stats sum to the total");
        q.finish().unwrap();
    }
}

/// Integration: a split launch with a user global offset must compose
/// the partition offset with the user's — every work-item observes the
/// same ids, sizes, and offset as on a single device, bit-identically.
#[test]
fn offset_split_launch_matches_single_device() {
    const SRC: &str = "__kernel void probe(__global float *x) {
        size_t i = get_global_id(0);
        x[i] = (float)(get_group_id(0) * 1000u + get_num_groups(0) * 10u)
             + (float)get_global_offset(0)
             + (float)get_global_size(0) * 0.5f
             + (float)get_local_id(0);
    }";
    let n = 96usize;
    let run = |device: Arc<dyn Device>| -> Vec<f32> {
        let ctx = Arc::new(Context::new(device));
        let q = CommandQueue::new(ctx.clone());
        let program = Program::build(SRC).unwrap();
        let buf = ctx.create_buffer(n * 4).unwrap();
        let up = q.enqueue_write_slice(buf, &vec![0.0f32; n], &[]).unwrap();
        let mut k = Kernel::new(&program, "probe").unwrap();
        k.set_arg(0, KernelArg::Buf(buf)).unwrap();
        let ev = q
            .enqueue_nd_range_at(&program, &k, [32, 1, 1], [4, 1, 1], [24, 0, 0], &[up])
            .unwrap();
        let rd = q.enqueue_read_buffer(buf, 0, n * 4, &[ev]).unwrap();
        let out: Vec<f32> = rd.wait_vec().unwrap();
        q.finish().unwrap();
        out
    };
    let base = run(Arc::new(BasicDevice::new(EngineKind::Serial)));
    // The offset window must actually have been written.
    assert!(base[24..56].iter().any(|&v| v != 0.0), "probe kernel wrote its window");
    let engines =
        [EngineKind::Serial, EngineKind::GangVector(4), EngineKind::Bytecode(8)];
    for policy in policies() {
        let pname = policy.name();
        let got = run(group_of(&engines, policy));
        for (j, (a, b)) in base.iter().zip(&got).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "[{pname}] x[{j}] = {b}, single-device {a}"
            );
        }
    }
}

/// Integration: on a split launch, the event's profiling span must cover
/// every member's execution — `start_ns` is the earliest member chunk
/// start and `end_ns` the latest chunk end, so the span bounds the
/// busiest member's accumulated busy time and the usual monotonic
/// profile ordering still holds.
#[test]
fn split_launch_profiling_covers_member_spans() {
    const SRC: &str = "__kernel void spin(__global float *x) {
        float acc = 0.0f;
        for (int i = 0; i < 256; i = i + 1) {
            acc = acc + (float)i * 0.5f;
        }
        x[get_group_id(0)] = acc;
    }";
    let n = 48usize;
    let device = group_of(
        &[EngineKind::Serial, EngineKind::GangVector(4), EngineKind::Bytecode(8)],
        Arc::new(Dynamic::fixed(4)),
    );
    let ctx = Arc::new(Context::new(device));
    let q = CommandQueue::new(ctx.clone());
    let program = Program::build(SRC).unwrap();
    let buf = ctx.create_buffer(n * 4).unwrap();
    let mut k = Kernel::new(&program, "spin").unwrap();
    k.set_arg(0, KernelArg::Buf(buf)).unwrap();
    let ev = q.enqueue_nd_range(&program, &k, [n, 1, 1], [1, 1, 1], &[]).unwrap();
    ev.wait().unwrap();
    let p = ev.profile();
    assert!(p.queued_ns <= p.submitted_ns, "queued before submitted");
    assert!(p.submitted_ns <= p.start_ns, "submitted before the first chunk starts");
    assert!(p.start_ns < p.end_ns, "a split launch has a non-empty exec span");
    let sched = ev.sched_stats().expect("group launch reports scheduler stats");
    let busiest = sched.devices.iter().map(|d| d.busy_ns).max().unwrap_or(0);
    assert!(busiest > 0, "members recorded busy time");
    // Each member runs its chunks sequentially inside [start, end], so
    // the event span must be at least the busiest member's busy time.
    assert!(
        ev.duration_ns() >= u128::from(busiest),
        "event span {} ns must cover the busiest member's {} ns",
        ev.duration_ns(),
        busiest
    );
    q.finish().unwrap();
}

/// Integration: accumulated scheduler stats across a multi-pass suite
/// app stay consistent — member rows keep their shape and the grand
/// totals match the aggregate launch stats.
#[test]
fn sched_stats_accumulate_consistently_across_passes() {
    let app = all_apps(SizeClass::Small)
        .into_iter()
        .find(|a| a.passes.len() > 1)
        .expect("the suite has a multi-pass app");
    let engines =
        [EngineKind::Serial, EngineKind::GangVector(4), EngineKind::Bytecode(8)];
    let device = group_of(&engines, Arc::new(Dynamic::new()));
    let program = Program::build(app.source).unwrap();
    let r = runner::run_with_program(&app, device, QueueProperties::InOrder, program).unwrap();
    runner::verify(&app, &r.buffers).unwrap();
    let sched = r.sched.expect("group run reports scheduler stats");
    assert_eq!(sched.devices.len(), 3);
    assert_eq!(sched.groups(), r.stats.workgroups);
    assert_eq!(sched.total().workgroups, r.stats.workgroups);
    assert_eq!(sched.total().dispatches(), r.stats.dispatches());
    assert!(sched.imbalance() >= 1.0, "imbalance is a max/mean ratio");
}
