//! Counter assertions for the lane-batched vector engine: the compile-side
//! uniformity export, the ≥width× interpreter-dispatch reduction on a
//! uniform-control kernel (the ISSUE acceptance criterion), and the
//! divergence fallback accounting.

use poclrs::exec::value::SP_GLOBAL;
use poclrs::exec::{gang, mem, vecgang, LaunchCtx, MemoryRefs, VVal};
use poclrs::frontend::compile;
use poclrs::kcc::{compile_workgroup, CompileOptions, WorkGroupFunction};

const VECADD: &str = "__kernel void vecadd(__global const float *a, __global const float *b, __global float *c) {
    size_t i = get_global_id(0);
    c[i] = a[i] + b[i];
}";

const DIVERGE: &str = "__kernel void dv(__global float *x) {
    size_t i = get_global_id(0);
    float v = x[i];
    if (v > 4.0f) { v = v * 2.0f; } else { v = v - 1.0f; }
    x[i] = v;
}";

const N: usize = 32;
const LOCAL: usize = 8;

/// Compile `src` for an N-element 1D launch and run it with either gang
/// engine over `bufs` f32 buffers laid out back to back in global memory.
/// Returns the accumulated stats and the final contents of every buffer.
fn run_gangs(
    src: &str,
    bufs: &[Vec<f32>],
    vector: bool,
    width: usize,
) -> (gang::GangStats, Vec<Vec<f32>>) {
    let m = compile(src).unwrap();
    let wgf =
        compile_workgroup(&m.kernels[0], [LOCAL, 1, 1], &CompileOptions::default()).unwrap();
    let mut global = vec![0u8; bufs.iter().map(|b| b.len() * 4).sum::<usize>()];
    let mut args = Vec::new();
    let mut offsets = Vec::new();
    let mut off = 0usize;
    for b in bufs {
        mem::write_f32s(&mut global, off, b);
        args.push(VVal::ptr(SP_GLOBAL, off as u64));
        offsets.push((off, b.len()));
        off += b.len() * 4;
    }
    let mut local_mem = vec![0u8; 1];
    let mut total = gang::GangStats::default();
    for g in 0..N / LOCAL {
        let ctx = LaunchCtx {
            group_id: [g as u64, 0, 0],
            num_groups: [(N / LOCAL) as u64, 1, 1],
            global_offset: [0; 3],
            local_size: [LOCAL, 1, 1],
            work_dim: 1,
        };
        let mut mem_refs = MemoryRefs { global: &mut global, local: &mut local_mem };
        let s = if vector {
            vecgang::run_workgroup(&wgf, &args, &mut mem_refs, &ctx, width).unwrap()
        } else {
            gang::run_workgroup(&wgf, &args, &mut mem_refs, &ctx, width).unwrap()
        };
        total.gangs += s.gangs;
        total.diverged += s.diverged;
        total.vector_insts += s.vector_insts;
        total.uniform_insts += s.uniform_insts;
        total.lane_insts += s.lane_insts;
    }
    let out = offsets.iter().map(|&(o, n)| mem::read_f32s(&global, o, n)).collect();
    (total, out)
}

fn vecadd_bufs() -> Vec<Vec<f32>> {
    vec![
        (0..N).map(|i| i as f32).collect(),
        (0..N).map(|i| (i * 3) as f32).collect(),
        vec![0.0; N],
    ]
}

#[test]
fn vector_engine_cuts_dispatches_by_width_on_uniform_kernel() {
    let width = 8;
    let (scalar, out_s) = run_gangs(VECADD, &vecadd_bufs(), false, width);
    let (vector, out_v) = run_gangs(VECADD, &vecadd_bufs(), true, width);
    let expect: Vec<f32> = (0..N).map(|i| (i + i * 3) as f32).collect();
    assert_eq!(out_s[2], expect);
    assert_eq!(out_v[2], expect);
    assert_eq!(vector.diverged, 0, "vecadd has uniform control flow");
    assert!(vector.vector_insts > 0, "lane-batched dispatches recorded");
    assert!(vector.uniform_insts > 0, "once-per-gang uniform dispatches recorded");
    assert_eq!(vector.lane_insts, 0, "no per-lane fallback on a uniform kernel");
    // ISSUE acceptance criterion: ≥ width× fewer interpreter dispatches
    // than the per-lane gang engine on a uniform-control kernel.
    assert!(
        scalar.dispatches() >= width * vector.dispatches(),
        "scalar {} vs vector {} (width {width})",
        scalar.dispatches(),
        vector.dispatches()
    );
}

#[test]
fn divergent_kernel_falls_back_per_lane_and_still_agrees() {
    let width = 8;
    let input: Vec<f32> = (0..N).map(|i| i as f32).collect();
    let (scalar, out_s) = run_gangs(DIVERGE, &[input.clone()], false, width);
    let (vector, out_v) = run_gangs(DIVERGE, &[input], true, width);
    assert_eq!(out_s[0], out_v[0], "divergent fallback preserves semantics");
    assert!(vector.diverged > 0, "the v>4 branch splits at least one gang");
    assert!(vector.lane_insts > 0, "fallback dispatches are per-lane");
    assert_eq!(scalar.gangs, vector.gangs, "same gang partition in both engines");
}

#[test]
fn workgroup_function_exports_uniformity_metadata() {
    let m = compile(VECADD).unwrap();
    let wgf: WorkGroupFunction =
        compile_workgroup(&m.kernels[0], [LOCAL, 1, 1], &CompileOptions::default()).unwrap();
    assert_eq!(wgf.reg_uniform.len(), wgf.reg_fn.reg_count() as usize);
    assert_eq!(wgf.region_divergent.len(), wgf.regions.len());
    assert!(wgf.stats.uniform_regs > 0, "{:?}", wgf.stats);
    assert_eq!(wgf.stats.divergent_regions, 0, "{:?}", wgf.stats);

    let m = compile(DIVERGE).unwrap();
    let wgf =
        compile_workgroup(&m.kernels[0], [LOCAL, 1, 1], &CompileOptions::default()).unwrap();
    assert!(wgf.stats.divergent_regions >= 1, "{:?}", wgf.stats);
    assert!(wgf.region_divergent.iter().any(|&d| d));
}
