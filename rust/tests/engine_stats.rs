//! Counter assertions for the lane-batched vector engine, the
//! threaded-bytecode tier and the template-jit tier: the compile-side
//! uniformity export, the ≥width× interpreter-dispatch reduction on a
//! uniform-control kernel (the ISSUE acceptance criterion), the bytecode
//! tier's strict dispatch reduction over the vector engine, the
//! divergence fallback accounting, and the jit tier's bit-identical
//! results, per-region fallback accounting and `POCLRS_JIT=0` kill
//! switch.

use poclrs::exec::value::SP_GLOBAL;
use poclrs::exec::{bytecode, gang, jit, mem, vecgang, LaunchCtx, MemoryRefs, VVal};
use poclrs::frontend::compile;
use poclrs::kcc::{compile_workgroup, CompileOptions, WorkGroupFunction};

const VECADD: &str = "__kernel void vecadd(__global const float *a, __global const float *b, __global float *c) {
    size_t i = get_global_id(0);
    c[i] = a[i] + b[i];
}";

const DIVERGE: &str = "__kernel void dv(__global float *x) {
    size_t i = get_global_id(0);
    float v = x[i];
    if (v > 4.0f) { v = v * 2.0f; } else { v = v - 1.0f; }
    x[i] = v;
}";

/// Uniform first region (covered by bytecode), divergent second region
/// (left to the vector interpreter) — exercises the per-region fallback.
const DIVERGE_BARRIER: &str = "__kernel void dvb(__global float *x) {
    size_t i = get_global_id(0);
    float v = x[i] * 2.0f;
    barrier(CLK_LOCAL_MEM_FENCE);
    if (v > 8.0f) { v = v + 3.0f; } else { v = v - 1.0f; }
    x[i] = v;
}";

/// Jittable first region (float arithmetic only), then a region whose
/// integer `min`/`max` elementals the jit templates reject while the
/// bytecode tier still covers them — exercises the jit's per-region
/// fallback onto the bytecode interpreter.
const JIT_MIXED: &str = "__kernel void jm(__global float *x) {
    size_t i = get_global_id(0);
    x[i] = x[i] * 2.0f + 1.0f;
    barrier(CLK_LOCAL_MEM_FENCE);
    int v = (int)x[i];
    x[i] = (float)(min(v, 40) + max(v, 3));
}";

const N: usize = 32;
const LOCAL: usize = 8;

/// Serialises the tests that read (or, for the kill-switch test, write)
/// the `POCLRS_JIT` environment variable — `cargo test` runs tests in
/// parallel threads sharing one process environment.
static JIT_ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn jit_lock() -> std::sync::MutexGuard<'static, ()> {
    JIT_ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Which engine `run_gangs` drives.
#[derive(Clone, Copy, PartialEq)]
enum Eng {
    Scalar,
    Vector,
    Bytecode,
    Jit,
}

/// Compile `src` for an N-element 1D launch and run it with the chosen
/// gang engine over `bufs` f32 buffers laid out back to back in global
/// memory. Returns the accumulated stats and the final contents of every
/// buffer.
fn run_gangs(
    src: &str,
    bufs: &[Vec<f32>],
    engine: Eng,
    width: usize,
) -> (gang::GangStats, Vec<Vec<f32>>) {
    let m = compile(src).unwrap();
    let mut wgf =
        compile_workgroup(&m.kernels[0], [LOCAL, 1, 1], &CompileOptions::default()).unwrap();
    if engine == Eng::Jit {
        // The default compile options carry gang_width 0, so the
        // compiler does not attach a jit program; lower explicitly for
        // the width this run actually uses.
        jit::attach(&mut wgf, width);
    }
    let mut global = vec![0u8; bufs.iter().map(|b| b.len() * 4).sum::<usize>()];
    let mut args = Vec::new();
    let mut offsets = Vec::new();
    let mut off = 0usize;
    for b in bufs {
        mem::write_f32s(&mut global, off, b);
        args.push(VVal::ptr(SP_GLOBAL, off as u64));
        offsets.push((off, b.len()));
        off += b.len() * 4;
    }
    let mut local_mem = vec![0u8; 1];
    let mut total = gang::GangStats::default();
    for g in 0..N / LOCAL {
        let ctx = LaunchCtx {
            group_id: [g as u64, 0, 0],
            num_groups: [(N / LOCAL) as u64, 1, 1],
            global_offset: [0; 3],
            local_size: [LOCAL, 1, 1],
            work_dim: 1,
        };
        let mut mem_refs = MemoryRefs { global: &mut global, local: &mut local_mem };
        let s = match engine {
            Eng::Scalar => gang::run_workgroup(&wgf, &args, &mut mem_refs, &ctx, width).unwrap(),
            Eng::Vector => {
                vecgang::run_workgroup(&wgf, &args, &mut mem_refs, &ctx, width).unwrap()
            }
            Eng::Bytecode => {
                bytecode::run_workgroup(&wgf, &args, &mut mem_refs, &ctx, width).unwrap()
            }
            Eng::Jit => jit::run_workgroup(&wgf, &args, &mut mem_refs, &ctx, width).unwrap(),
        };
        total.gangs += s.gangs;
        total.diverged += s.diverged;
        total.vector_insts += s.vector_insts;
        total.uniform_insts += s.uniform_insts;
        total.lane_insts += s.lane_insts;
        total.bytecode_insts += s.bytecode_insts;
        total.bytecode_gangs += s.bytecode_gangs;
        total.bytecode_fallbacks += s.bytecode_fallbacks;
        total.jit_insts += s.jit_insts;
        total.jit_gangs += s.jit_gangs;
        total.jit_fallbacks += s.jit_fallbacks;
    }
    let out = offsets.iter().map(|&(o, n)| mem::read_f32s(&global, o, n)).collect();
    (total, out)
}

fn vecadd_bufs() -> Vec<Vec<f32>> {
    vec![
        (0..N).map(|i| i as f32).collect(),
        (0..N).map(|i| (i * 3) as f32).collect(),
        vec![0.0; N],
    ]
}

#[test]
fn vector_engine_cuts_dispatches_by_width_on_uniform_kernel() {
    let width = 8;
    let (scalar, out_s) = run_gangs(VECADD, &vecadd_bufs(), Eng::Scalar, width);
    let (vector, out_v) = run_gangs(VECADD, &vecadd_bufs(), Eng::Vector, width);
    let expect: Vec<f32> = (0..N).map(|i| (i + i * 3) as f32).collect();
    assert_eq!(out_s[2], expect);
    assert_eq!(out_v[2], expect);
    assert_eq!(vector.diverged, 0, "vecadd has uniform control flow");
    assert!(vector.vector_insts > 0, "lane-batched dispatches recorded");
    assert!(vector.uniform_insts > 0, "once-per-gang uniform dispatches recorded");
    assert_eq!(vector.lane_insts, 0, "no per-lane fallback on a uniform kernel");
    // ISSUE acceptance criterion: ≥ width× fewer interpreter dispatches
    // than the per-lane gang engine on a uniform-control kernel.
    assert!(
        scalar.dispatches() >= width * vector.dispatches(),
        "scalar {} vs vector {} (width {width})",
        scalar.dispatches(),
        vector.dispatches()
    );
}

#[test]
fn bytecode_tier_strictly_reduces_dispatches_and_agrees() {
    for width in [4usize, 8] {
        let (vector, out_v) = run_gangs(VECADD, &vecadd_bufs(), Eng::Vector, width);
        let (bc, out_b) = run_gangs(VECADD, &vecadd_bufs(), Eng::Bytecode, width);
        // Bit-identical results (f32 equality is exact here — both paths
        // run the same evaluation kernels).
        for (v, b) in out_v.iter().zip(&out_b) {
            let vb: Vec<u32> = v.iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
            assert_eq!(vb, bb, "bytecode output diverges at width {width}");
        }
        assert!(bc.bytecode_gangs > 0, "covered regions ran through bytecode");
        assert_eq!(bc.bytecode_fallbacks, 0, "vecadd is fully coverable");
        assert_eq!(bc.diverged, 0);
        assert!(bc.bytecode_insts > 0, "bytecode dispatches recorded");
        // Superinstruction fusion makes the reduction strict, not just ≤.
        assert!(
            bc.dispatches() < vector.dispatches(),
            "bytecode {} !< vector {} (width {width})",
            bc.dispatches(),
            vector.dispatches()
        );
        assert_eq!(bc.gangs, vector.gangs, "same gang partition in both engines");
    }
}

#[test]
fn bytecode_tier_falls_back_on_divergent_regions() {
    let width = 8;
    let input: Vec<f32> = (0..N).map(|i| i as f32).collect();
    let (vector, out_v) = run_gangs(DIVERGE_BARRIER, &[input.clone()], Eng::Vector, width);
    let (bc, out_b) = run_gangs(DIVERGE_BARRIER, &[input], Eng::Bytecode, width);
    assert_eq!(out_v[0], out_b[0], "fallback preserves semantics");
    // The uniform pre-barrier region runs through bytecode; the statically
    // divergent post-barrier region has no lowered bytecode and the engine
    // must account each such gang-region as a fallback, not silently
    // misreport coverage.
    assert!(bc.bytecode_gangs > 0, "uniform region covered: {bc:?}");
    assert!(
        bc.bytecode_fallbacks > 0,
        "divergent region must fall back to the vector interpreter: {bc:?}"
    );
    assert_eq!(bc.gangs, vector.gangs);

    // A kernel whose only region is divergent lowers to no bytecode at
    // all and degrades wholesale to the vector engine.
    let input: Vec<f32> = (0..N).map(|i| i as f32).collect();
    let (bc2, out2) = run_gangs(DIVERGE, &[input.clone()], Eng::Bytecode, width);
    let (v2, outv2) = run_gangs(DIVERGE, &[input], Eng::Vector, width);
    assert_eq!(out2[0], outv2[0]);
    assert_eq!(bc2.bytecode_insts, 0, "no bytecode to run: {bc2:?}");
    assert_eq!(bc2.gangs, v2.gangs);
}

#[test]
fn divergent_kernel_falls_back_per_lane_and_still_agrees() {
    let width = 8;
    let input: Vec<f32> = (0..N).map(|i| i as f32).collect();
    let (scalar, out_s) = run_gangs(DIVERGE, &[input.clone()], Eng::Scalar, width);
    let (vector, out_v) = run_gangs(DIVERGE, &[input], Eng::Vector, width);
    assert_eq!(out_s[0], out_v[0], "divergent fallback preserves semantics");
    assert!(vector.diverged > 0, "the v>4 branch splits at least one gang");
    assert!(vector.lane_insts > 0, "fallback dispatches are per-lane");
    assert_eq!(scalar.gangs, vector.gangs, "same gang partition in both engines");
}

#[test]
fn workgroup_function_exports_uniformity_metadata() {
    let m = compile(VECADD).unwrap();
    let wgf: WorkGroupFunction =
        compile_workgroup(&m.kernels[0], [LOCAL, 1, 1], &CompileOptions::default()).unwrap();
    assert_eq!(wgf.reg_uniform.len(), wgf.reg_fn.reg_count() as usize);
    assert_eq!(wgf.region_divergent.len(), wgf.regions.len());
    assert!(wgf.stats.uniform_regs > 0, "{:?}", wgf.stats);
    assert_eq!(wgf.stats.divergent_regions, 0, "{:?}", wgf.stats);
    // The uniform kernel lowers completely into the bytecode tier, with
    // at least one fused superinstruction (the a[i]/b[i] gep+load pairs).
    assert!(wgf.bytecode.is_some(), "{:?}", wgf.stats);
    assert_eq!(wgf.stats.bytecode_regions, wgf.stats.regions, "{:?}", wgf.stats);
    assert!(wgf.stats.bytecode_fused > 0, "{:?}", wgf.stats);

    let m = compile(DIVERGE).unwrap();
    let wgf =
        compile_workgroup(&m.kernels[0], [LOCAL, 1, 1], &CompileOptions::default()).unwrap();
    assert!(wgf.stats.divergent_regions >= 1, "{:?}", wgf.stats);
    assert!(wgf.region_divergent.iter().any(|&d| d));
    assert!(
        wgf.stats.bytecode_regions < wgf.stats.regions,
        "divergent regions are not lowered: {:?}",
        wgf.stats
    );
}

// ---------------------------------------------------------------------
// Template-jit tier
// ---------------------------------------------------------------------

/// True when the host actually compiles the x86-64 templates in; on any
/// other host the jit engine must degrade wholesale to the bytecode
/// tier (and these tests assert exactly that).
fn jit_host() -> bool {
    cfg!(all(target_arch = "x86_64", target_os = "linux"))
}

#[test]
fn jit_tier_bit_identical_and_counts() {
    let _g = jit_lock();
    for width in [4usize, 8] {
        let (bc, out_b) = run_gangs(VECADD, &vecadd_bufs(), Eng::Bytecode, width);
        let (jt, out_j) = run_gangs(VECADD, &vecadd_bufs(), Eng::Jit, width);
        for (b, j) in out_b.iter().zip(&out_j) {
            let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
            let jb: Vec<u32> = j.iter().map(|x| x.to_bits()).collect();
            assert_eq!(bb, jb, "jit output diverges at width {width}");
        }
        assert_eq!(jt.gangs, bc.gangs, "same gang partition in both tiers");
        if jit_host() {
            assert!(jt.jit_gangs > 0, "covered regions ran jitted: {jt:?}");
            assert!(jt.jit_insts > 0, "jitted instructions counted: {jt:?}");
            assert_eq!(jt.jit_fallbacks, 0, "vecadd is fully jittable: {jt:?}");
            assert_eq!(jt.bytecode_gangs, 0, "nothing left for the interpreter: {jt:?}");
        } else {
            assert_eq!(jt.jit_gangs, 0, "jit tier is compiled out: {jt:?}");
            assert!(jt.bytecode_gangs > 0, "wholesale bytecode fallback: {jt:?}");
        }
    }
}

#[test]
fn jit_tier_falls_back_per_region_on_unsupported_math() {
    let _g = jit_lock();
    let width = 8;
    let input: Vec<f32> = (0..N).map(|i| i as f32).collect();
    let (bc, out_b) = run_gangs(JIT_MIXED, &[input.clone()], Eng::Bytecode, width);
    let (jt, out_j) = run_gangs(JIT_MIXED, &[input], Eng::Jit, width);
    let bb: Vec<u32> = out_b[0].iter().map(|x| x.to_bits()).collect();
    let jb: Vec<u32> = out_j[0].iter().map(|x| x.to_bits()).collect();
    assert_eq!(bb, jb, "per-region fallback preserves semantics");
    assert_eq!(jt.gangs, bc.gangs);
    if jit_host() {
        // The float region runs jitted; the integer-math region is
        // rejected by the templates and must be accounted as a fallback
        // onto the bytecode interpreter — never silently dropped.
        assert!(jt.jit_gangs > 0, "float region jitted: {jt:?}");
        assert!(jt.jit_fallbacks > 0, "integer-math region fell back: {jt:?}");
        assert!(jt.bytecode_gangs > 0, "fallback ran through bytecode: {jt:?}");
    } else {
        assert_eq!(jt.jit_gangs, 0, "{jt:?}");
    }

    // Compile-side accounting for the same kernel: jitted + rejected
    // regions must partition exactly what the bytecode tier lowered.
    let m = compile(JIT_MIXED).unwrap();
    let mut wgf =
        compile_workgroup(&m.kernels[0], [LOCAL, 1, 1], &CompileOptions::default()).unwrap();
    jit::attach(&mut wgf, width);
    assert_eq!(
        wgf.stats.jit_regions + wgf.stats.jit_fallbacks,
        wgf.stats.bytecode_regions,
        "{:?}",
        wgf.stats
    );
    if jit_host() {
        assert!(wgf.stats.jit_regions >= 1, "{:?}", wgf.stats);
        assert!(wgf.stats.jit_fallbacks >= 1, "{:?}", wgf.stats);
        let jp = wgf.jit.as_ref().expect("jit program attached");
        assert_eq!(jp.covered_regions(), wgf.stats.jit_regions);
    } else {
        assert!(wgf.jit.is_none());
        assert_eq!(wgf.stats.jit_regions, 0, "{:?}", wgf.stats);
    }
}

/// Removes `POCLRS_JIT` on drop so a failing assertion cannot leak the
/// kill switch into the other (lock-serialised) jit tests.
struct JitEnvGuard;

impl Drop for JitEnvGuard {
    fn drop(&mut self) {
        std::env::remove_var("POCLRS_JIT");
    }
}

#[test]
fn jit_kill_switch_disables_the_tier_wholesale() {
    let _g = jit_lock();
    std::env::set_var("POCLRS_JIT", "0");
    let _guard = JitEnvGuard;

    // attach becomes a no-op that still reports every region as a
    // fallback, so `--stats` stays honest about why nothing was jitted.
    let m = compile(VECADD).unwrap();
    let mut wgf =
        compile_workgroup(&m.kernels[0], [LOCAL, 1, 1], &CompileOptions::default()).unwrap();
    jit::attach(&mut wgf, 8);
    assert!(wgf.jit.is_none(), "kill switch must prevent attachment");
    assert_eq!(wgf.stats.jit_regions, 0, "{:?}", wgf.stats);
    assert_eq!(wgf.stats.jit_fallbacks, wgf.stats.bytecode_regions, "{:?}", wgf.stats);

    // The jit engine then degrades wholesale to the bytecode tier with
    // identical results and zero jit activity.
    let (bc, out_b) = run_gangs(VECADD, &vecadd_bufs(), Eng::Bytecode, 8);
    let (jt, out_j) = run_gangs(VECADD, &vecadd_bufs(), Eng::Jit, 8);
    for (b, j) in out_b.iter().zip(&out_j) {
        let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
        let jb: Vec<u32> = j.iter().map(|x| x.to_bits()).collect();
        assert_eq!(bb, jb, "kill-switch fallback preserves results");
    }
    assert_eq!(jt.jit_gangs, 0, "{jt:?}");
    assert_eq!(jt.jit_insts, 0, "{jt:?}");
    assert!(jt.bytecode_gangs > 0, "wholesale bytecode fallback: {jt:?}");
    assert_eq!(jt.gangs, bc.gangs);
}
