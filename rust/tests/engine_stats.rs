//! Counter assertions for the lane-batched vector engine and the
//! threaded-bytecode tier: the compile-side uniformity export, the
//! ≥width× interpreter-dispatch reduction on a uniform-control kernel
//! (the ISSUE acceptance criterion), the bytecode tier's strict dispatch
//! reduction over the vector engine, and the divergence fallback
//! accounting.

use poclrs::exec::value::SP_GLOBAL;
use poclrs::exec::{bytecode, gang, mem, vecgang, LaunchCtx, MemoryRefs, VVal};
use poclrs::frontend::compile;
use poclrs::kcc::{compile_workgroup, CompileOptions, WorkGroupFunction};

const VECADD: &str = "__kernel void vecadd(__global const float *a, __global const float *b, __global float *c) {
    size_t i = get_global_id(0);
    c[i] = a[i] + b[i];
}";

const DIVERGE: &str = "__kernel void dv(__global float *x) {
    size_t i = get_global_id(0);
    float v = x[i];
    if (v > 4.0f) { v = v * 2.0f; } else { v = v - 1.0f; }
    x[i] = v;
}";

/// Uniform first region (covered by bytecode), divergent second region
/// (left to the vector interpreter) — exercises the per-region fallback.
const DIVERGE_BARRIER: &str = "__kernel void dvb(__global float *x) {
    size_t i = get_global_id(0);
    float v = x[i] * 2.0f;
    barrier(CLK_LOCAL_MEM_FENCE);
    if (v > 8.0f) { v = v + 3.0f; } else { v = v - 1.0f; }
    x[i] = v;
}";

const N: usize = 32;
const LOCAL: usize = 8;

/// Which engine `run_gangs` drives.
#[derive(Clone, Copy, PartialEq)]
enum Eng {
    Scalar,
    Vector,
    Bytecode,
}

/// Compile `src` for an N-element 1D launch and run it with the chosen
/// gang engine over `bufs` f32 buffers laid out back to back in global
/// memory. Returns the accumulated stats and the final contents of every
/// buffer.
fn run_gangs(
    src: &str,
    bufs: &[Vec<f32>],
    engine: Eng,
    width: usize,
) -> (gang::GangStats, Vec<Vec<f32>>) {
    let m = compile(src).unwrap();
    let wgf =
        compile_workgroup(&m.kernels[0], [LOCAL, 1, 1], &CompileOptions::default()).unwrap();
    let mut global = vec![0u8; bufs.iter().map(|b| b.len() * 4).sum::<usize>()];
    let mut args = Vec::new();
    let mut offsets = Vec::new();
    let mut off = 0usize;
    for b in bufs {
        mem::write_f32s(&mut global, off, b);
        args.push(VVal::ptr(SP_GLOBAL, off as u64));
        offsets.push((off, b.len()));
        off += b.len() * 4;
    }
    let mut local_mem = vec![0u8; 1];
    let mut total = gang::GangStats::default();
    for g in 0..N / LOCAL {
        let ctx = LaunchCtx {
            group_id: [g as u64, 0, 0],
            num_groups: [(N / LOCAL) as u64, 1, 1],
            global_offset: [0; 3],
            local_size: [LOCAL, 1, 1],
            work_dim: 1,
        };
        let mut mem_refs = MemoryRefs { global: &mut global, local: &mut local_mem };
        let s = match engine {
            Eng::Scalar => gang::run_workgroup(&wgf, &args, &mut mem_refs, &ctx, width).unwrap(),
            Eng::Vector => {
                vecgang::run_workgroup(&wgf, &args, &mut mem_refs, &ctx, width).unwrap()
            }
            Eng::Bytecode => {
                bytecode::run_workgroup(&wgf, &args, &mut mem_refs, &ctx, width).unwrap()
            }
        };
        total.gangs += s.gangs;
        total.diverged += s.diverged;
        total.vector_insts += s.vector_insts;
        total.uniform_insts += s.uniform_insts;
        total.lane_insts += s.lane_insts;
        total.bytecode_insts += s.bytecode_insts;
        total.bytecode_gangs += s.bytecode_gangs;
        total.bytecode_fallbacks += s.bytecode_fallbacks;
    }
    let out = offsets.iter().map(|&(o, n)| mem::read_f32s(&global, o, n)).collect();
    (total, out)
}

fn vecadd_bufs() -> Vec<Vec<f32>> {
    vec![
        (0..N).map(|i| i as f32).collect(),
        (0..N).map(|i| (i * 3) as f32).collect(),
        vec![0.0; N],
    ]
}

#[test]
fn vector_engine_cuts_dispatches_by_width_on_uniform_kernel() {
    let width = 8;
    let (scalar, out_s) = run_gangs(VECADD, &vecadd_bufs(), Eng::Scalar, width);
    let (vector, out_v) = run_gangs(VECADD, &vecadd_bufs(), Eng::Vector, width);
    let expect: Vec<f32> = (0..N).map(|i| (i + i * 3) as f32).collect();
    assert_eq!(out_s[2], expect);
    assert_eq!(out_v[2], expect);
    assert_eq!(vector.diverged, 0, "vecadd has uniform control flow");
    assert!(vector.vector_insts > 0, "lane-batched dispatches recorded");
    assert!(vector.uniform_insts > 0, "once-per-gang uniform dispatches recorded");
    assert_eq!(vector.lane_insts, 0, "no per-lane fallback on a uniform kernel");
    // ISSUE acceptance criterion: ≥ width× fewer interpreter dispatches
    // than the per-lane gang engine on a uniform-control kernel.
    assert!(
        scalar.dispatches() >= width * vector.dispatches(),
        "scalar {} vs vector {} (width {width})",
        scalar.dispatches(),
        vector.dispatches()
    );
}

#[test]
fn bytecode_tier_strictly_reduces_dispatches_and_agrees() {
    for width in [4usize, 8] {
        let (vector, out_v) = run_gangs(VECADD, &vecadd_bufs(), Eng::Vector, width);
        let (bc, out_b) = run_gangs(VECADD, &vecadd_bufs(), Eng::Bytecode, width);
        // Bit-identical results (f32 equality is exact here — both paths
        // run the same evaluation kernels).
        for (v, b) in out_v.iter().zip(&out_b) {
            let vb: Vec<u32> = v.iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
            assert_eq!(vb, bb, "bytecode output diverges at width {width}");
        }
        assert!(bc.bytecode_gangs > 0, "covered regions ran through bytecode");
        assert_eq!(bc.bytecode_fallbacks, 0, "vecadd is fully coverable");
        assert_eq!(bc.diverged, 0);
        assert!(bc.bytecode_insts > 0, "bytecode dispatches recorded");
        // Superinstruction fusion makes the reduction strict, not just ≤.
        assert!(
            bc.dispatches() < vector.dispatches(),
            "bytecode {} !< vector {} (width {width})",
            bc.dispatches(),
            vector.dispatches()
        );
        assert_eq!(bc.gangs, vector.gangs, "same gang partition in both engines");
    }
}

#[test]
fn bytecode_tier_falls_back_on_divergent_regions() {
    let width = 8;
    let input: Vec<f32> = (0..N).map(|i| i as f32).collect();
    let (vector, out_v) = run_gangs(DIVERGE_BARRIER, &[input.clone()], Eng::Vector, width);
    let (bc, out_b) = run_gangs(DIVERGE_BARRIER, &[input], Eng::Bytecode, width);
    assert_eq!(out_v[0], out_b[0], "fallback preserves semantics");
    // The uniform pre-barrier region runs through bytecode; the statically
    // divergent post-barrier region has no lowered bytecode and the engine
    // must account each such gang-region as a fallback, not silently
    // misreport coverage.
    assert!(bc.bytecode_gangs > 0, "uniform region covered: {bc:?}");
    assert!(
        bc.bytecode_fallbacks > 0,
        "divergent region must fall back to the vector interpreter: {bc:?}"
    );
    assert_eq!(bc.gangs, vector.gangs);

    // A kernel whose only region is divergent lowers to no bytecode at
    // all and degrades wholesale to the vector engine.
    let input: Vec<f32> = (0..N).map(|i| i as f32).collect();
    let (bc2, out2) = run_gangs(DIVERGE, &[input.clone()], Eng::Bytecode, width);
    let (v2, outv2) = run_gangs(DIVERGE, &[input], Eng::Vector, width);
    assert_eq!(out2[0], outv2[0]);
    assert_eq!(bc2.bytecode_insts, 0, "no bytecode to run: {bc2:?}");
    assert_eq!(bc2.gangs, v2.gangs);
}

#[test]
fn divergent_kernel_falls_back_per_lane_and_still_agrees() {
    let width = 8;
    let input: Vec<f32> = (0..N).map(|i| i as f32).collect();
    let (scalar, out_s) = run_gangs(DIVERGE, &[input.clone()], Eng::Scalar, width);
    let (vector, out_v) = run_gangs(DIVERGE, &[input], Eng::Vector, width);
    assert_eq!(out_s[0], out_v[0], "divergent fallback preserves semantics");
    assert!(vector.diverged > 0, "the v>4 branch splits at least one gang");
    assert!(vector.lane_insts > 0, "fallback dispatches are per-lane");
    assert_eq!(scalar.gangs, vector.gangs, "same gang partition in both engines");
}

#[test]
fn workgroup_function_exports_uniformity_metadata() {
    let m = compile(VECADD).unwrap();
    let wgf: WorkGroupFunction =
        compile_workgroup(&m.kernels[0], [LOCAL, 1, 1], &CompileOptions::default()).unwrap();
    assert_eq!(wgf.reg_uniform.len(), wgf.reg_fn.reg_count() as usize);
    assert_eq!(wgf.region_divergent.len(), wgf.regions.len());
    assert!(wgf.stats.uniform_regs > 0, "{:?}", wgf.stats);
    assert_eq!(wgf.stats.divergent_regions, 0, "{:?}", wgf.stats);
    // The uniform kernel lowers completely into the bytecode tier, with
    // at least one fused superinstruction (the a[i]/b[i] gep+load pairs).
    assert!(wgf.bytecode.is_some(), "{:?}", wgf.stats);
    assert_eq!(wgf.stats.bytecode_regions, wgf.stats.regions, "{:?}", wgf.stats);
    assert!(wgf.stats.bytecode_fused > 0, "{:?}", wgf.stats);

    let m = compile(DIVERGE).unwrap();
    let wgf =
        compile_workgroup(&m.kernels[0], [LOCAL, 1, 1], &CompileOptions::default()).unwrap();
    assert!(wgf.stats.divergent_regions >= 1, "{:?}", wgf.stats);
    assert!(wgf.region_divergent.iter().any(|&d| d));
    assert!(
        wgf.stats.bytecode_regions < wgf.stats.regions,
        "divergent regions are not lowered: {:?}",
        wgf.stats
    );
}
