//! Property test over the event DAG: random dependency graphs of vecadd
//! commands produce the same buffer contents whether they run on an
//! in-order queue (program order, no explicit edges) or on an
//! out-of-order queue whose wait-lists encode exactly the data
//! dependencies (RAW, WAR and WAW edges per buffer).

use std::sync::Arc;

use poclrs::cl::{CommandQueue, Context, Event, Kernel, KernelArg, Program, QueueProperties};
use poclrs::devices::{basic::BasicDevice, Device, EngineKind};
use poclrs::testing::check;

const SRC: &str = "__kernel void vecadd(__global const float *a, __global const float *b, __global float *c) {
    size_t i = get_global_id(0);
    c[i] = a[i] + b[i];
}";

const N: usize = 32;
const NBUFS: usize = 4;

/// One command: bufs[dst] = bufs[a] + bufs[b] (element-wise).
#[derive(Clone, Copy)]
struct Cmd {
    a: usize,
    b: usize,
    dst: usize,
}

/// Reference semantics: apply the commands in program order.
fn native(init: &[Vec<f32>], cmds: &[Cmd]) -> Vec<Vec<f32>> {
    let mut bufs = init.to_vec();
    for c in cmds {
        let out: Vec<f32> =
            (0..N).map(|i| bufs[c.a][i] + bufs[c.b][i]).collect();
        bufs[c.dst] = out;
    }
    bufs
}

/// Run the command list on a queue. For out-of-order queues the wait-list
/// of each command carries its exact data-dependency edges; in-order
/// queues rely on implicit chaining (empty wait-lists).
fn run_queue(init: &[Vec<f32>], cmds: &[Cmd], props: QueueProperties) -> Vec<Vec<f32>> {
    let device: Arc<dyn Device> = Arc::new(BasicDevice::new(EngineKind::Serial));
    let ctx = Arc::new(Context::new(device));
    let queue = CommandQueue::with_properties(ctx.clone(), props);
    let program = Program::build(SRC).unwrap();

    let handles: Vec<_> = init.iter().map(|_| ctx.create_buffer(N * 4).unwrap()).collect();
    let explicit_edges = props == QueueProperties::OutOfOrder;

    // Per-buffer dependency bookkeeping.
    let mut last_writer: Vec<Option<Event>> = Vec::new();
    let mut readers_since: Vec<Vec<Event>> = vec![Vec::new(); NBUFS];
    for (h, data) in handles.iter().zip(init) {
        let ev = queue.enqueue_write_slice(*h, data, &[]).unwrap();
        last_writer.push(Some(ev));
    }

    for c in cmds {
        let mut wait: Vec<Event> = Vec::new();
        if explicit_edges {
            // RAW: wait on the writers of the sources and the destination
            // (the kernel reads a and b; the dst edge is WAW).
            for src in [c.a, c.b, c.dst] {
                if let Some(w) = &last_writer[src] {
                    wait.push(w.clone());
                }
            }
            // WAR: wait on every reader of dst since its last write.
            wait.extend(readers_since[c.dst].iter().cloned());
        }
        let mut k = Kernel::new(&program, "vecadd").unwrap();
        k.set_arg(0, KernelArg::Buf(handles[c.a])).unwrap();
        k.set_arg(1, KernelArg::Buf(handles[c.b])).unwrap();
        k.set_arg(2, KernelArg::Buf(handles[c.dst])).unwrap();
        let ev = queue.enqueue_nd_range(&program, &k, [N, 1, 1], [8, 1, 1], &wait).unwrap();
        readers_since[c.a].push(ev.clone());
        readers_since[c.b].push(ev.clone());
        last_writer[c.dst] = Some(ev);
        readers_since[c.dst].clear();
    }

    // Read-backs wait on each buffer's last writer.
    let mut reads = Vec::new();
    for (i, h) in handles.iter().enumerate() {
        let wait: Vec<Event> = if explicit_edges {
            last_writer[i].iter().cloned().collect()
        } else {
            Vec::new()
        };
        reads.push(queue.enqueue_read_buffer(*h, 0, N * 4, &wait).unwrap());
    }
    queue.flush();
    let out = reads.iter().map(|r| r.wait_vec::<f32>().unwrap()).collect();
    queue.finish().unwrap();
    out
}

#[test]
fn prop_random_dags_agree_in_and_out_of_order() {
    check(6, |rng| {
        let init: Vec<Vec<f32>> =
            (0..NBUFS).map(|_| rng.f32s(N, -4.0, 4.0)).collect();
        let ncmds = rng.range(3, 8);
        let cmds: Vec<Cmd> = (0..ncmds)
            .map(|_| Cmd { a: rng.below(NBUFS), b: rng.below(NBUFS), dst: rng.below(NBUFS) })
            .collect();
        let expect = native(&init, &cmds);
        let in_order = run_queue(&init, &cmds, QueueProperties::InOrder);
        assert_eq!(in_order, expect, "in-order queue must match program order");
        let out_of_order = run_queue(&init, &cmds, QueueProperties::OutOfOrder);
        assert_eq!(
            out_of_order, expect,
            "out-of-order queue with exact dependency edges must match program order"
        );
    });
}
