//! Cross-cutting integration tests: host API flows, the §4.1 cache, the
//! PJRT runtime against a generated artifact, and Table 1 reporting.

use std::sync::Arc;

use poclrs::cl::{CommandQueue, Context, Kernel, KernelArg, Platform, Program};
use poclrs::kcc::CompileOptions;

#[test]
fn specialization_cache_shared_across_enqueues() {
    let platform = Platform::default_platform();
    let ctx = Arc::new(Context::new(platform.device("basic-serial").unwrap()));
    let q = CommandQueue::new(ctx.clone());
    let program = Program::build(
        "__kernel void k(__global float *x) { x[get_global_id(0)] += 1.0f; }",
    )
    .unwrap();
    let buf = ctx.create_buffer(64 * 4).unwrap();
    ctx.write_f32(buf, &vec![0.0; 64]).unwrap();
    let mut k = Kernel::new(&program, "k").unwrap();
    k.set_arg(0, KernelArg::Buf(buf)).unwrap();
    for _ in 0..5 {
        q.enqueue_nd_range(&program, &k, [64, 1, 1], [16, 1, 1], &[]).unwrap();
    }
    q.enqueue_nd_range(&program, &k, [64, 1, 1], [32, 1, 1], &[]).unwrap();
    // Work-group functions are specialised at *enqueue* time (§4.1), so
    // the cache counters are exact before the queue even flushes.
    let s = program.cache_stats();
    assert_eq!(s.misses, 2, "two local sizes → two compiles");
    assert_eq!(s.memory_hits, 4);
    assert_eq!(s.disk_hits, 0, "no persistent cache attached to Program::build");
    q.finish().unwrap();
    let out = ctx.read_f32(buf, 64).unwrap();
    assert!(out.iter().all(|&v| v == 6.0));
}

#[test]
fn capability_table_is_table1_shaped() {
    let platform = Platform::default_platform();
    let t = platform.capability_table();
    // The Table 1 axes: TLP / ILP / DLP per device.
    assert!(t.contains("TLP") && t.contains("ILP") && t.contains("DLP"));
    assert!(t.lines().count() >= 6);
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_runtime_roundtrip_if_artifacts_exist() {
    // Soft-skip when `make artifacts` hasn't run (CI without python).
    let path = std::path::Path::new("artifacts/matmul.hlo.txt");
    if !path.exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    use poclrs::runtime::{ArgData, ArgSpec, PjrtRuntime};
    let rt = PjrtRuntime::cpu().unwrap();
    let exe = rt.load(path).unwrap();
    let n = 64usize;
    let a = vec![1.0f32; n * n];
    let b = vec![2.0f32; n * n];
    let spec = ArgSpec::f32(&[n * n]);
    let out = exe
        .execute_f32(&[(ArgData::F32(&a), &spec), (ArgData::F32(&b), &spec)])
        .unwrap();
    assert_eq!(out[0].len(), n * n);
    assert!(out[0].iter().all(|&v| (v - 2.0 * n as f32).abs() < 1e-3));
    // Second load hits the executable cache.
    let _ = rt.load(path).unwrap();
    assert_eq!(rt.cached(), 1);
}

#[test]
fn spmd_options_respected_for_pjrt_style_devices() {
    let m = poclrs::frontend::compile(
        "__kernel void k(__global float *x) { x[get_global_id(0)] = 1.0f; }",
    )
    .unwrap();
    let opts = CompileOptions { spmd: true, ..Default::default() };
    let wgf = poclrs::kcc::compile_workgroup(&m.kernels[0], [64, 1, 1], &opts).unwrap();
    assert_eq!(wgf.stats.wi_loops, 0, "SPMD path skips WI-loop materialisation");
}
