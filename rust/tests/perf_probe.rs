use std::sync::Arc;
use std::time::Instant;
use poclrs::devices::{basic::BasicDevice, Device, EngineKind};
use poclrs::suite::{app_by_name, runner, SizeClass};

/// Perf probe used by the §Perf iteration log. Ignored by default
/// (meaningful only in --release): `cargo test --release --test perf_probe -- --ignored --nocapture`.
#[test]
#[ignore]
fn perf_probe() {
    for name in ["Mandelbrot", "MatrixMultiplication"] {
        let app = app_by_name(name, SizeClass::Bench).unwrap();
        let d: Arc<dyn Device> = Arc::new(BasicDevice::new(EngineKind::Gang(8)));
        runner::run_and_verify(&app, d.clone()).unwrap();
        let t0 = Instant::now();
        for _ in 0..3 { runner::run_on_device(&app, d.clone()).unwrap(); }
        println!("PERF {name}: {:.1} ms/run", t0.elapsed().as_secs_f64()*1e3/3.0);
    }
}
