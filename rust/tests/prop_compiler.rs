//! Property tests over the kernel compiler: for a family of generated
//! kernels (random barrier placement, conditional barriers, b-loops) and
//! random launch geometries, (1) the structural invariants hold and
//! (2) all engines — serial, fiber, per-lane gang, and the lane-batched
//! vector gang — agree bit-for-bit.

use std::sync::Arc;

use poclrs::cl::{CommandQueue, Context, Kernel, KernelArg, Program};
use poclrs::devices::{basic::BasicDevice, Device, EngineKind};
use poclrs::testing::{check, Rng};

/// Generate a random kernel from a template family that exercises
/// barriers, conditional barriers, loops and private state.
fn gen_kernel(rng: &mut Rng) -> String {
    let mut body = String::from(
        "size_t i = get_local_id(0);\n size_t g = get_global_id(0);\n float v = x[g];\n",
    );
    let stmts = rng.range(1, 4);
    for s in 0..stmts {
        match rng.below(5) {
            0 => body.push_str("v = v * 1.5f + 1.0f;\n"),
            // Every template ends with a barrier after its last read of
            // `t`, so composed statements never race on local memory
            // (reads and writes of `t` in the same parallel region are UB
            // and engines could legitimately differ).
            1 => body.push_str(
                "t[i] = v;\n barrier(CLK_LOCAL_MEM_FENCE);\n v = t[(i + 1u) % get_local_size(0)];\n barrier(CLK_LOCAL_MEM_FENCE);\n",
            ),
            2 => body.push_str(&format!(
                "for (int k{s} = 0; k{s} < {}; k{s}++) {{\n t[i] = v;\n barrier(CLK_LOCAL_MEM_FENCE);\n v += t[(i + {}u) % get_local_size(0)];\n barrier(CLK_LOCAL_MEM_FENCE);\n }}\n",
                rng.range(1, 3),
                rng.range(1, 3)
            )),
            3 => body.push_str(&format!(
                "if (c > {}) {{\n t[i] = v + 2.0f;\n barrier(CLK_LOCAL_MEM_FENCE);\n v = t[0];\n barrier(CLK_LOCAL_MEM_FENCE);\n }}\n",
                rng.below(2)
            )),
            _ => body.push_str("if (v > 2.0f) { v = v - 1.0f; } else { v = v + 3.0f; }\n"),
        }
    }
    body.push_str("x[g] = v;\n");
    format!("__kernel void k(__global float *x, __local float *t, int c) {{\n{body}\n}}")
}

fn run_engine(src: &str, engine: EngineKind, input: &[f32], local: usize, c: i32) -> Vec<f32> {
    let device: Arc<dyn Device> = Arc::new(BasicDevice::new(engine));
    let ctx = Arc::new(Context::new(device));
    let q = CommandQueue::new(ctx.clone());
    let program = Program::build(src).unwrap();
    let x = ctx.create_buffer(input.len() * 4).unwrap();
    ctx.write_f32(x, input).unwrap();
    let mut k = Kernel::new(&program, "k").unwrap();
    k.set_arg(0, KernelArg::Buf(x)).unwrap();
    k.set_arg(1, KernelArg::LocalSize(local * 4)).unwrap();
    k.set_arg(2, KernelArg::I32(c)).unwrap();
    q.enqueue_nd_range(&program, &k, [input.len(), 1, 1], [local, 1, 1], &[]).unwrap();
    q.finish().unwrap();
    ctx.read_f32(x, input.len()).unwrap()
}

#[test]
fn prop_engines_agree_on_random_barrier_kernels() {
    check(30, |rng| {
        let src = gen_kernel(rng);
        let local = *rng.pick(&[2usize, 4, 8]);
        let groups = rng.range(1, 3);
        let n = local * groups;
        let input = rng.f32s(n, 0.0, 4.0);
        let c = rng.below(3) as i32;
        let serial = run_engine(&src, EngineKind::Serial, &input, local, c);
        for engine in [
            EngineKind::Gang(4),
            EngineKind::Gang(8),
            EngineKind::GangVector(4),
            EngineKind::GangVector(8),
            EngineKind::Fiber,
        ] {
            let got = run_engine(&src, engine, &input, local, c);
            assert_eq!(serial, got, "engine {engine:?} disagrees\nkernel:\n{src}");
        }
    });
}

#[test]
fn prop_compiler_invariants_on_random_kernels() {
    use poclrs::kcc::{compile_workgroup, taildup, CompileOptions};
    check(40, |rng| {
        let src = gen_kernel(rng);
        let m = poclrs::frontend::compile(&src).unwrap();
        let local = [*rng.pick(&[1usize, 3, 8]), 1, 1];
        let wgf = compile_workgroup(&m.kernels[0], local, &CompileOptions::default()).unwrap();
        // Invariants: verified IR both forms; ≤1 imm pred per barrier in
        // region form; no barriers left in loop form.
        poclrs::ir::verify::verify(&wgf.reg_fn).unwrap();
        poclrs::ir::verify::verify(&wgf.loop_fn).unwrap();
        assert!(taildup::max_imm_preds(&wgf.reg_fn) <= 1, "Prop.1 fixed point\n{src}");
        assert_eq!(poclrs::ir::verify::barrier_count(&wgf.loop_fn), 0);
        // Region sanity.
        poclrs::kcc::regions::check_regions(&wgf.reg_fn, &wgf.regions)
            .unwrap_or_else(|e| panic!("{e}\n{src}"));
    });
}
