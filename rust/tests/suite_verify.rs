//! Integration: every suite application must produce baseline-identical
//! results on every CPU-style device (the correctness half of Fig. 12-14),
//! under both queue execution modes (in-order and out-of-order).

use std::sync::Arc;

use poclrs::cl::QueueProperties;
use poclrs::devices::{basic::BasicDevice, threaded::ThreadedDevice, ttasim::TtaSimDevice, Device, EngineKind};
use poclrs::suite::{all_apps, runner, SizeClass};

fn devices() -> Vec<(&'static str, Arc<dyn Device>)> {
    vec![
        ("basic-serial", Arc::new(BasicDevice::new(EngineKind::Serial)) as Arc<dyn Device>),
        ("basic-gang8", Arc::new(BasicDevice::new(EngineKind::Gang(8)))),
        ("basic-gang4", Arc::new(BasicDevice::new(EngineKind::Gang(4)))),
        ("basic-gangvector8", Arc::new(BasicDevice::new(EngineKind::GangVector(8)))),
        ("basic-gangvector4", Arc::new(BasicDevice::new(EngineKind::GangVector(4)))),
        ("basic-fiber", Arc::new(BasicDevice::new(EngineKind::Fiber))),
        ("pthread-gang8", Arc::new(ThreadedDevice::new(EngineKind::Gang(8), 4))),
        ("pthread-gangvector8", Arc::new(ThreadedDevice::new(EngineKind::GangVector(8), 4))),
    ]
}

#[test]
fn all_apps_verify_on_all_devices_both_queue_modes() {
    let mut failures = Vec::new();
    for props in [QueueProperties::InOrder, QueueProperties::OutOfOrder] {
        for (dname, device) in devices() {
            for app in all_apps(SizeClass::Small) {
                if let Err(e) = runner::run_and_verify_with_queue(&app, device.clone(), props) {
                    failures.push(format!("{props:?}/{dname}/{}: {e}", app.name));
                }
            }
        }
    }
    assert!(failures.is_empty(), "suite failures:\n{}", failures.join("\n"));
}

#[test]
fn all_apps_verify_on_ttasim_both_modes() {
    let mut failures = Vec::new();
    for horizontal in [false, true] {
        let device: Arc<dyn Device> = Arc::new(TtaSimDevice::new(horizontal));
        for app in all_apps(SizeClass::Small) {
            match runner::run_and_verify(&app, device.clone()) {
                Ok(r) => {
                    assert!(r.stats.cycles > 0, "{}: cycle model engaged", app.name);
                }
                Err(e) => failures.push(format!("ttasim(h={horizontal})/{}: {e}", app.name)),
            }
        }
    }
    assert!(failures.is_empty(), "ttasim failures:\n{}", failures.join("\n"));
}
