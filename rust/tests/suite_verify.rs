//! Integration: every suite application must produce baseline-identical
//! results on every CPU-style device (the correctness half of Fig. 12-14),
//! under both queue execution modes (in-order and out-of-order), plus the
//! threaded-bytecode tier's acceptance criteria: suite-wide bit-identical
//! results, ≥half of the suite's parallel regions lowered to bytecode,
//! and strictly fewer interpreter dispatches than the vector engine on
//! the anchor apps.
//!
//! Setting `POCLRS_ENGINE=bytecode` restricts the device matrix to the
//! bytecode-tier devices; `POCLRS_ENGINE=jit` restricts it to the
//! template-jit devices; `POCLRS_ENGINE=multidev` restricts it to the
//! heterogeneous device-group entries (the dedicated CI legs).

use std::sync::Arc;

use poclrs::cl::{CommandQueue, Context, Kernel, KernelArg, Program, QueueProperties};
use poclrs::devices::{
    basic::BasicDevice, threaded::ThreadedDevice, ttasim::TtaSimDevice, Device, EngineKind,
};
use poclrs::kcc::opt::OptLevel;
use poclrs::sched::{DeviceGroup, Dynamic, SchedPolicy, StaticSplit};
use poclrs::suite::{all_apps, runner, App, BufInit, SizeClass};

/// Heterogeneous 3-member group with deliberately uneven engines
/// (serial, lane-batched vector at width 4, threaded-bytecode at width
/// 8) under the given partitioning policy.
fn multidev(policy: Arc<dyn SchedPolicy>) -> Arc<dyn Device> {
    let members: Vec<Arc<dyn Device>> = vec![
        Arc::new(BasicDevice::new(EngineKind::Serial)),
        Arc::new(BasicDevice::new(EngineKind::GangVector(4))),
        Arc::new(BasicDevice::new(EngineKind::Bytecode(8))),
    ];
    Arc::new(DeviceGroup::new("multidev", members, policy).expect("valid group"))
}

fn devices() -> Vec<(&'static str, Arc<dyn Device>)> {
    let all: Vec<(&'static str, Arc<dyn Device>)> = vec![
        ("basic-serial", Arc::new(BasicDevice::new(EngineKind::Serial)) as Arc<dyn Device>),
        ("basic-gang8", Arc::new(BasicDevice::new(EngineKind::Gang(8)))),
        ("basic-gang4", Arc::new(BasicDevice::new(EngineKind::Gang(4)))),
        ("basic-gangvector8", Arc::new(BasicDevice::new(EngineKind::GangVector(8)))),
        ("basic-gangvector4", Arc::new(BasicDevice::new(EngineKind::GangVector(4)))),
        ("basic-bytecode8", Arc::new(BasicDevice::new(EngineKind::Bytecode(8)))),
        ("basic-bytecode4", Arc::new(BasicDevice::new(EngineKind::Bytecode(4)))),
        ("basic-jit8", Arc::new(BasicDevice::new(EngineKind::Jit(8)))),
        ("basic-jit4", Arc::new(BasicDevice::new(EngineKind::Jit(4)))),
        ("basic-fiber", Arc::new(BasicDevice::new(EngineKind::Fiber))),
        ("pthread-gang8", Arc::new(ThreadedDevice::new(EngineKind::Gang(8), 4))),
        ("pthread-gangvector8", Arc::new(ThreadedDevice::new(EngineKind::GangVector(8), 4))),
        ("pthread-bytecode8", Arc::new(ThreadedDevice::new(EngineKind::Bytecode(8), 4))),
        ("pthread-jit8", Arc::new(ThreadedDevice::new(EngineKind::Jit(8), 4))),
        ("multidev-dynamic", multidev(Arc::new(Dynamic::new()))),
        ("multidev-static", multidev(Arc::new(StaticSplit::new(vec![1.0, 2.0, 3.0])))),
    ];
    // The CI bytecode/jit/multidev legs run the same matrix restricted
    // to the tier under test.
    match std::env::var("POCLRS_ENGINE").as_deref() {
        Ok("bytecode") => all.into_iter().filter(|(name, _)| name.contains("bytecode")).collect(),
        Ok("jit") => all.into_iter().filter(|(name, _)| name.contains("jit")).collect(),
        Ok("multidev") => all.into_iter().filter(|(name, _)| name.contains("multidev")).collect(),
        _ => all,
    }
}

#[test]
fn all_apps_verify_on_all_devices_both_queue_modes() {
    let mut failures = Vec::new();
    for props in [QueueProperties::InOrder, QueueProperties::OutOfOrder] {
        for (dname, device) in devices() {
            for app in all_apps(SizeClass::Small) {
                if let Err(e) = runner::run_and_verify_with_queue(&app, device.clone(), props) {
                    failures.push(format!("{props:?}/{dname}/{}: {e}", app.name));
                }
            }
        }
    }
    assert!(failures.is_empty(), "suite failures:\n{}", failures.join("\n"));
}

#[test]
fn all_apps_verify_on_ttasim_both_modes() {
    if matches!(std::env::var("POCLRS_ENGINE").as_deref(), Ok("bytecode") | Ok("jit")) {
        return; // the bytecode/jit CI legs skip the TTA matrix
    }
    let mut failures = Vec::new();
    for horizontal in [false, true] {
        let device: Arc<dyn Device> = Arc::new(TtaSimDevice::new(horizontal));
        for app in all_apps(SizeClass::Small) {
            match runner::run_and_verify(&app, device.clone()) {
                Ok(r) => {
                    assert!(r.stats.cycles > 0, "{}: cycle model engaged", app.name);
                }
                Err(e) => failures.push(format!("ttasim(h={horizontal})/{}: {e}", app.name)),
            }
        }
    }
    assert!(failures.is_empty(), "ttasim failures:\n{}", failures.join("\n"));
}

/// Satellite for the global-offset fix: an offset launch through the
/// host API must produce the same window of results on every device in
/// the matrix — including the heterogeneous groups, whose sub-launches
/// must compose the partition offset with the user's global offset.
#[test]
fn global_offset_launches_identical_across_devices() {
    const SRC: &str = "__kernel void off(__global float *x) {
        size_t i = get_global_id(0);
        x[i] = (float)(i * 3u) + (float)get_global_offset(0);
    }";
    let n = 64usize;
    // global [16] at offset 32 with local [8]: ids 32..48 write 3*i+32,
    // the rest of the buffer stays zero.
    let expect: Vec<f32> =
        (0..n).map(|j| if (32..48).contains(&j) { (3 * j + 32) as f32 } else { 0.0 }).collect();
    for (dname, device) in devices() {
        let ctx = Arc::new(Context::new(device));
        let q = CommandQueue::new(ctx.clone());
        let program = Program::build(SRC).unwrap();
        let buf = ctx.create_buffer(n * 4).unwrap();
        let up = q.enqueue_write_slice(buf, &vec![0.0f32; n], &[]).unwrap();
        let mut k = Kernel::new(&program, "off").unwrap();
        k.set_arg(0, KernelArg::Buf(buf)).unwrap();
        let ev = q
            .enqueue_nd_range_at(&program, &k, [16, 1, 1], [8, 1, 1], [32, 0, 0], &[up])
            .unwrap_or_else(|e| panic!("{dname}: offset launch failed: {e}"));
        let rd = q.enqueue_read_buffer(buf, 0, n * 4, &[ev]).unwrap();
        let out: Vec<f32> = rd.wait_vec().unwrap();
        assert_eq!(out, expect, "{dname}: offset launch window");
        q.finish().unwrap();
    }
}

// ---------------------------------------------------------------------
// Heterogeneous device-group acceptance
// ---------------------------------------------------------------------

/// Acceptance: suite-wide bit-identical results between a 1-device run
/// and a 3-member heterogeneous group (uneven engines) under both the
/// `Static` and `Dynamic` policies, with the scheduler breakdown
/// accounting for every work-group.
#[test]
fn multidev_group_bit_identical_to_single_device_both_policies() {
    let policies: Vec<Arc<dyn SchedPolicy>> = vec![
        Arc::new(StaticSplit::new(vec![1.0, 4.0, 2.0])),
        Arc::new(StaticSplit::even()),
        Arc::new(Dynamic::fixed(1)),
        Arc::new(Dynamic::new()),
    ];
    for app in all_apps(SizeClass::Small) {
        let base_dev: Arc<dyn Device> = Arc::new(BasicDevice::new(EngineKind::Serial));
        let base = runner::run_with_program(
            &app,
            base_dev,
            QueueProperties::InOrder,
            Program::build(app.source).unwrap(),
        )
        .unwrap_or_else(|e| panic!("{} single-device baseline: {e}", app.name));
        runner::verify(&app, &base.buffers).unwrap();
        for policy in &policies {
            let pname = policy.name();
            let group = multidev(policy.clone());
            let r = runner::run_with_program(
                &app,
                group,
                QueueProperties::InOrder,
                Program::build(app.source).unwrap(),
            )
            .unwrap_or_else(|e| panic!("{} multidev[{pname}]: {e}", app.name));
            assert_bit_identical(
                &base.buffers,
                &r.buffers,
                &format!("{} single-device vs multidev[{pname}]", app.name),
            );
            let sched = r.sched.as_ref().unwrap_or_else(|| {
                panic!("{} multidev[{pname}]: group run must report scheduler stats", app.name)
            });
            assert_eq!(sched.devices.len(), 3, "{} multidev[{pname}]: member rows", app.name);
            assert_eq!(
                sched.groups(),
                r.stats.workgroups,
                "{} multidev[{pname}]: per-member groups must sum to the launch total",
                app.name
            );
        }
    }
}

// ---------------------------------------------------------------------
// Threaded-bytecode tier acceptance
// ---------------------------------------------------------------------

fn assert_bit_identical(a: &[BufInit], b: &[BufInit], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: buffer count");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        match (x, y) {
            (BufInit::F32(u), BufInit::F32(v)) => {
                assert_eq!(u.len(), v.len(), "{what}: buffer {i} length");
                for (j, (p, q)) in u.iter().zip(v).enumerate() {
                    assert_eq!(
                        p.to_bits(),
                        q.to_bits(),
                        "{what}: buffer {i}[{j}] {p} vs {q} not bit-identical"
                    );
                }
            }
            (BufInit::U32(u), BufInit::U32(v)) => assert_eq!(u, v, "{what}: buffer {i}"),
            _ => panic!("{what}: buffer {i} type mismatch"),
        }
    }
}

/// Run `app` on a basic device pinned to `level`, verify against the
/// native baseline, and return the run result.
fn run_at(app: &App, engine: EngineKind, level: OptLevel) -> runner::RunResult {
    let device: Arc<dyn Device> = Arc::new(BasicDevice::with_opt_level(engine, level));
    let program = Program::build(app.source).unwrap();
    let r = runner::run_with_program(app, device, QueueProperties::InOrder, program)
        .unwrap_or_else(|e| panic!("{} at {level:?} on {engine:?}: {e}", app.name));
    runner::verify(app, &r.buffers)
        .unwrap_or_else(|e| panic!("{} at {level:?} on {engine:?}: {e}", app.name));
    r
}

/// Acceptance: the bytecode tier lowers at least half of the suite's
/// parallel regions, never dispatches more than the vector engine, and
/// dispatches strictly less on the anchor apps (MatrixMultiplication and
/// BlackScholes, whose covered inner loops are superinstruction-dense).
#[test]
fn bytecode_tier_covers_suite_and_reduces_dispatches() {
    let mut covered = 0usize;
    let mut total_regions = 0usize;
    let mut anchors_seen = 0usize;
    let mut lines = Vec::new();
    for app in all_apps(SizeClass::Small) {
        let vec_run = run_at(&app, EngineKind::GangVector(4), OptLevel::O2);
        let bc_run = run_at(&app, EngineKind::Bytecode(4), OptLevel::O2);
        assert_bit_identical(
            &vec_run.buffers,
            &bc_run.buffers,
            &format!("{} gang-vector vs bytecode", app.name),
        );
        for (_, wgf) in bc_run.program.cached_specializations() {
            covered += wgf.stats.bytecode_regions;
            total_regions += wgf.stats.regions;
        }
        let dv = vec_run.stats.dispatches();
        let db = bc_run.stats.dispatches();
        lines.push(format!("{:<22} vector={dv:>9} bytecode={db:>9}", app.name));
        assert!(
            db <= dv,
            "{}: bytecode must never dispatch more than the vector engine (vector={dv}, bytecode={db})",
            app.name
        );
        if app.name == "MatrixMultiplication" || app.name == "BlackScholes" {
            anchors_seen += 1;
            assert!(
                db < dv,
                "{}: bytecode must strictly reduce dispatches (vector={dv}, bytecode={db})",
                app.name
            );
            assert!(
                bc_run.stats.bytecode_insts > 0,
                "{}: the anchor app must actually run bytecode",
                app.name
            );
        }
    }
    assert_eq!(anchors_seen, 2, "both anchor apps must be in the suite");
    assert!(
        covered * 2 >= total_regions,
        "bytecode must cover >=half of the suite's regions ({covered}/{total_regions}):\n{}",
        lines.join("\n")
    );
}

/// Acceptance: the bytecode tier is bit-identical to the serial engine
/// on every suite app at both O0 and O2 (i.e. the tier composes with the
/// optimizer without perturbing results).
#[test]
fn bytecode_tier_bit_identical_to_serial_at_o0_and_o2() {
    for app in all_apps(SizeClass::Small) {
        for level in [OptLevel::O0, OptLevel::O2] {
            let base = run_at(&app, EngineKind::Serial, level);
            let got = run_at(&app, EngineKind::Bytecode(4), level);
            assert_bit_identical(
                &base.buffers,
                &got.buffers,
                &format!("{} serial vs bytecode at {level:?}", app.name),
            );
        }
    }
}

// ---------------------------------------------------------------------
// Template-JIT tier acceptance
// ---------------------------------------------------------------------

/// Acceptance: the jit tier is bit-identical to the bytecode tier on
/// every suite app at both widths, every region it does not cover falls
/// back cleanly (the runs above would fail otherwise), and on x86-64
/// Linux at least half of the suite's parallel regions are jitted.
#[test]
fn jit_tier_bit_identical_and_covers_suite() {
    let jit_host = cfg!(all(target_arch = "x86_64", target_os = "linux"));
    let mut covered = 0usize;
    let mut total_regions = 0usize;
    let mut lines = Vec::new();
    for app in all_apps(SizeClass::Small) {
        for width in [4usize, 8] {
            let bc_run = run_at(&app, EngineKind::Bytecode(width), OptLevel::O2);
            let jit_run = run_at(&app, EngineKind::Jit(width), OptLevel::O2);
            assert_bit_identical(
                &bc_run.buffers,
                &jit_run.buffers,
                &format!("{} bytecode vs jit (width {width})", app.name),
            );
            if width == 4 {
                for (_, wgf) in jit_run.program.cached_specializations() {
                    covered += wgf.stats.jit_regions;
                    total_regions += wgf.stats.regions;
                    // Uncovered regions must be accounted for, not lost:
                    // jitted + rejected = everything the bytecode tier
                    // lowered.
                    assert_eq!(
                        wgf.stats.jit_regions + wgf.stats.jit_fallbacks,
                        wgf.stats.bytecode_regions,
                        "{}: jit coverage must partition the bytecode regions",
                        app.name
                    );
                }
                lines.push(format!(
                    "{:<22} jit={covered:>4}/{total_regions:<4}",
                    app.name
                ));
            }
        }
    }
    if jit_host {
        assert!(
            covered * 2 >= total_regions,
            "jit must cover >=half of the suite's regions ({covered}/{total_regions}):\n{}",
            lines.join("\n")
        );
    } else {
        assert_eq!(covered, 0, "non-x86-64 hosts compile the jit tier out");
    }
}
