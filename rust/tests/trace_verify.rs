//! Tracer verification: an enabled tracer must capture all five runtime
//! layers (queue, compiler, cache, scheduler, engines) as schema-valid
//! Chrome trace JSON; complete spans must nest per thread even under
//! concurrent out-of-order queues; and a disabled tracer must record
//! nothing at all.
//!
//! The tracer is process-global state, so every test here serialises on
//! one lock, drains residue before its run, and disables collection
//! before draining its own events.

use std::sync::{Arc, Mutex, MutexGuard};

use poclrs::cl::{Program, QueueProperties};
use poclrs::devices::{basic::BasicDevice, Device, EngineKind};
use poclrs::sched::{DeviceGroup, Dynamic};
use poclrs::suite::{all_apps, runner, SizeClass};
use poclrs::trace::{self, chrome, json};

/// Tests that toggle the process-global tracer hold this for their whole
/// body so they never observe each other's events.
static TRACER: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    TRACER.lock().unwrap_or_else(|e| e.into_inner())
}

/// A two-member heterogeneous group, so launches go through the split
/// scheduler and the trace covers the `sched` layer too.
fn group_device() -> Arc<dyn Device> {
    let members: Vec<Arc<dyn Device>> = vec![
        Arc::new(BasicDevice::new(EngineKind::Serial)),
        Arc::new(BasicDevice::new(EngineKind::GangVector(4))),
    ];
    Arc::new(DeviceGroup::new("trace-group", members, Arc::new(Dynamic::fixed(4)))
        .expect("valid group"))
}

/// Acceptance: one traced suite-app run on a device group produces
/// Chrome trace JSON that parses, schema-validates, nests, and contains
/// spans from every one of the five instrumented layers.
#[test]
fn suite_run_traces_all_five_layers() {
    let _g = lock();
    trace::set_enabled(true);
    let _ = trace::take_events(); // drop residue from earlier tests
    let app = all_apps(SizeClass::Small).into_iter().next().expect("suite has apps");
    let r = runner::run_and_verify(&app, group_device()).expect("traced run verifies");
    assert!(r.stats.workgroups > 0);
    trace::set_enabled(false);
    let events = trace::take_events();
    assert!(!events.is_empty(), "an enabled tracer records events");
    let text = chrome::export_string(&events);
    let doc = json::parse(&text).expect("exporter emits valid JSON");
    let sum =
        json::validate_chrome_trace(&doc).expect("exporter emits schema-valid Chrome JSON");
    json::check_nesting(&doc).expect("complete spans nest per thread");
    for cat in ["queue", "compiler", "cache", "sched", "exec"] {
        assert!(
            sum.cats.contains(cat),
            "trace covers the `{cat}` layer (categories seen: {:?})",
            sum.cats
        );
    }
    assert!(sum.complete > 0, "complete spans present");
    assert!(sum.async_spans > 0, "async queue/sched spans present");
}

/// Property: spans stay properly nested per thread even when several
/// out-of-order queues on separate host threads trace concurrently —
/// per-thread buffering may interleave timestamps across threads, but
/// never produce overlapping (non-nested) spans within one.
#[test]
fn concurrent_out_of_order_queues_keep_spans_nested() {
    let _g = lock();
    trace::set_enabled(true);
    let _ = trace::take_events();
    let apps: Vec<_> = all_apps(SizeClass::Small).into_iter().take(3).collect();
    assert!(apps.len() >= 2, "need at least two apps for a concurrent run");
    std::thread::scope(|s| {
        for app in &apps {
            s.spawn(move || {
                let program = Program::build(app.source).expect("app compiles");
                let device: Arc<dyn Device> =
                    Arc::new(BasicDevice::new(EngineKind::GangVector(4)));
                let r = runner::run_with_program(
                    app,
                    device,
                    QueueProperties::OutOfOrder,
                    program,
                )
                .expect("out-of-order run completes");
                runner::verify(app, &r.buffers).expect("out-of-order run verifies");
            });
        }
    });
    trace::set_enabled(false);
    let events = trace::take_events();
    let text = chrome::export_string(&events);
    let doc = json::parse(&text).expect("valid JSON");
    let sum = json::validate_chrome_trace(&doc).expect("schema-valid under concurrency");
    json::check_nesting(&doc).expect("per-thread spans nest under concurrent queues");
    assert!(sum.threads.len() >= 2, "events came from multiple threads");
}

/// Zero-cost contract: with the tracer disabled, a full run records no
/// events whatsoever — instrumentation points must bail on the single
/// atomic check before touching any buffer.
#[test]
fn disabled_tracer_records_nothing() {
    let _g = lock();
    trace::set_enabled(false);
    let _ = trace::take_events(); // drop residue from earlier tests
    let app = all_apps(SizeClass::Small).into_iter().next().expect("suite has apps");
    let device: Arc<dyn Device> = Arc::new(BasicDevice::new(EngineKind::Serial));
    let r = runner::run_and_verify(&app, device).expect("untraced run verifies");
    assert!(r.stats.workgroups > 0);
    assert!(trace::take_events().is_empty(), "a disabled tracer records no events");
}
