//! Persistent kernel-binary cache integration tests: the `poclbin`
//! round-trip property over the whole suite, execution equivalence of
//! deserialized work-group functions on the serial/gang/vecgang engines,
//! and the warm-start acceptance criterion (a fresh `Program` against a
//! warm on-disk cache performs **zero** `compile_workgroup` calls).
//!
//! Every test uses its own temp directory — nothing here touches the
//! user-level default cache.

use std::path::PathBuf;
use std::sync::Arc;

use poclrs::cache::{poclbin, DiskCache};
use poclrs::cl::{Program, QueueProperties};
use poclrs::devices::{basic::BasicDevice, Device, EngineKind};
use poclrs::ir::print::print_function;
use poclrs::kcc::{compile_workgroup, CompileOptions, OptLevel};
use poclrs::suite::runner::RunResult;
use poclrs::suite::{all_apps, app_by_name, runner, App, BufInit, SizeClass};

/// Fresh per-test scratch directory under the system temp dir.
fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("poclrs-cache-test-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Run `app` on `device` through an explicit program, in-order.
fn run(app: &App, device: &Arc<dyn Device>, program: Program) -> RunResult {
    runner::run_with_program(app, device.clone(), QueueProperties::InOrder, program).unwrap()
}

fn assert_bit_identical(a: &[BufInit], b: &[BufInit], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: buffer count");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        match (x, y) {
            (BufInit::F32(u), BufInit::F32(v)) => {
                assert_eq!(u.len(), v.len(), "{what}: buffer {i} length");
                for (j, (p, q)) in u.iter().zip(v).enumerate() {
                    assert_eq!(
                        p.to_bits(),
                        q.to_bits(),
                        "{what}: buffer {i}[{j}] {p} vs {q} not bit-identical"
                    );
                }
            }
            (BufInit::U32(u), BufInit::U32(v)) => assert_eq!(u, v, "{what}: buffer {i}"),
            _ => panic!("{what}: buffer {i} type mismatch"),
        }
    }
}

/// Property: `poclbin` round-trips every suite app's module and every
/// pass's compiled work-group function, byte-for-byte deterministic and
/// identical under `ir::print`.
#[test]
fn poclbin_roundtrips_every_suite_app() {
    for app in all_apps(SizeClass::Small) {
        let module = poclrs::frontend::compile(app.source).unwrap();
        let bytes = poclbin::encode_module(&module);
        assert_eq!(bytes, poclbin::encode_module(&module), "{}: deterministic", app.name);
        let back = poclbin::decode_module(&bytes).unwrap();
        assert_eq!(module.kernels.len(), back.kernels.len(), "{}", app.name);
        for (a, b) in module.kernels.iter().zip(&back.kernels) {
            assert_eq!(print_function(a), print_function(b), "{}: module kernel", app.name);
            assert_eq!(a.reg_count(), b.reg_count(), "{}: reg high-water mark", app.name);
        }
        for pass in &app.passes {
            let k = module.kernel(pass.kernel).unwrap();
            let wgf = compile_workgroup(k, pass.local, &CompileOptions::default()).unwrap();
            let decoded = poclbin::decode_wgf(&poclbin::encode_wgf(&wgf)).unwrap();
            let ctx = format!("{}::{} @ {:?}", app.name, pass.kernel, pass.local);
            assert_eq!(print_function(&wgf.reg_fn), print_function(&decoded.reg_fn), "{ctx}");
            assert_eq!(print_function(&wgf.loop_fn), print_function(&decoded.loop_fn), "{ctx}");
            assert_eq!(wgf.local_size, decoded.local_size, "{ctx}");
            assert_eq!(wgf.reg_uniform, decoded.reg_uniform, "{ctx}");
            assert_eq!(wgf.region_divergent, decoded.region_divergent, "{ctx}");
            assert_eq!(wgf.regions.len(), decoded.regions.len(), "{ctx}");
            assert_eq!(format!("{:?}", wgf.stats), format!("{:?}", decoded.stats), "{ctx}");
        }
    }
}

/// Deserialized work-group functions must execute bit-identically to the
/// in-memory build on every CPU engine class (serial WI loops, per-lane
/// gang, lane-batched vector gang).
#[test]
fn deserialized_programs_execute_bit_identically() {
    let engines = [EngineKind::Serial, EngineKind::Gang(4), EngineKind::GangVector(4)];
    for app in all_apps(SizeClass::Small) {
        for engine in engines {
            let device: Arc<dyn Device> = Arc::new(BasicDevice::new(engine));
            let what = format!("{} on {:?}", app.name, engine);

            // In-memory build + run.
            let p1 = Program::build(app.source).unwrap();
            let r1 = run(&app, &device, p1);
            runner::verify(&app, &r1.buffers).unwrap();

            // Serialize program + specialisations, rebuild, rerun.
            let bytes = r1.program.binaries();
            let p2 = Program::from_binary(&bytes).unwrap();
            let r2 = run(&app, &device, p2);
            let s2 = r2.program.cache_stats();
            assert_eq!(s2.misses, 0, "{what}: binary-built program must not compile");
            assert!(s2.memory_hits > 0, "{what}: embedded entries must be used");
            assert_bit_identical(&r1.buffers, &r2.buffers, &what);
        }
    }
}

/// Acceptance criterion: a fresh `Program` built from the same source
/// against a warm on-disk cache performs zero `compile_workgroup` calls,
/// across single-pass, multi-pass, and barrier-heavy apps.
#[test]
fn warm_disk_cache_compiles_nothing() {
    let dir = tmpdir("warm");
    let device: Arc<dyn Device> = Arc::new(BasicDevice::new(EngineKind::Serial));
    for name in ["DCT", "BitonicSort", "Reduction"] {
        let app = app_by_name(name, SizeClass::Small).unwrap();

        // Cold process: empty cache, everything compiles + writes back.
        let disk1 = Arc::new(DiskCache::at(&dir).unwrap());
        let p1 = Program::build_cached(app.source, Some(disk1.clone())).unwrap();
        let r1 = run(&app, &device, p1);
        let s1 = r1.program.cache_stats();
        assert!(s1.misses > 0, "{name}: cold start compiles");
        assert_eq!(s1.disk_hits, 0, "{name}: cold cache has nothing to offer");
        assert_eq!(disk1.stats().writes as usize, s1.misses, "{name}: every compile written back");

        // Warm "process": fresh Program, fresh DiskCache handle, same dir.
        let disk2 = Arc::new(DiskCache::at(&dir).unwrap());
        let p2 = Program::build_cached(app.source, Some(disk2.clone())).unwrap();
        let r2 = run(&app, &device, p2);
        let s2 = r2.program.cache_stats();
        assert_eq!(s2.misses, 0, "{name}: warm start performs ZERO compile_workgroup calls");
        assert_eq!(s2.disk_hits as u64, disk2.stats().hits, "{name}: warm lookups hit disk");
        assert!(s2.disk_hits > 0, "{name}: disk served the specialisations");
        assert_bit_identical(&r1.buffers, &r2.buffers, name);
        runner::verify(&app, &r2.buffers).unwrap();
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Options that differ in any field address different disk entries: a
/// gang-width-8 device never reads a serial device's artifact.
#[test]
fn disk_entries_are_split_by_device_options() {
    let dir = tmpdir("split");
    let app = app_by_name("SimpleConvolution", SizeClass::Small).unwrap();
    let serial: Arc<dyn Device> = Arc::new(BasicDevice::new(EngineKind::Serial));
    let vec8: Arc<dyn Device> = Arc::new(BasicDevice::new(EngineKind::GangVector(8)));

    let disk = Arc::new(DiskCache::at(&dir).unwrap());
    let p1 = Program::build_cached(app.source, Some(disk.clone())).unwrap();
    let r1 = run(&app, &serial, p1);
    let compiled_serial = r1.program.cache_stats().misses;
    assert!(compiled_serial > 0);

    // Same source, different device class → different keys → fresh compiles.
    let p2 = Program::build_cached(app.source, Some(disk.clone())).unwrap();
    let r2 = run(&app, &vec8, p2);
    let s2 = r2.program.cache_stats();
    assert_eq!(s2.disk_hits, 0, "gang-width-8 options must not hit serial entries");
    assert_eq!(s2.misses, compiled_serial, "same kernels compile afresh for the new options");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance criterion: `opt_level` participates in the cache key — the
/// same device at O0 vs O2 addresses distinct disk entries, and repeat
/// runs at either level hit their own.
#[test]
fn disk_entries_are_split_by_opt_level() {
    let dir = tmpdir("optsplit");
    let app = app_by_name("MatrixMultiplication", SizeClass::Small).unwrap();
    let o2: Arc<dyn Device> =
        Arc::new(BasicDevice::with_opt_level(EngineKind::Serial, OptLevel::O2));
    let o0: Arc<dyn Device> =
        Arc::new(BasicDevice::with_opt_level(EngineKind::Serial, OptLevel::O0));

    let disk = Arc::new(DiskCache::at(&dir).unwrap());
    let p1 = Program::build_cached(app.source, Some(disk.clone())).unwrap();
    let r1 = run(&app, &o2, p1);
    let compiled_o2 = r1.program.cache_stats().misses;
    assert!(compiled_o2 > 0);

    // Same source, same device class, different opt level → fresh compiles.
    let p2 = Program::build_cached(app.source, Some(disk.clone())).unwrap();
    let r2 = run(&app, &o0, p2);
    let s2 = r2.program.cache_stats();
    assert_eq!(s2.disk_hits, 0, "O0 must never be served an O2 artifact");
    assert_eq!(s2.misses, compiled_o2, "same kernels compile afresh at the other level");

    // Re-running at O2 hits the original entries.
    let p3 = Program::build_cached(app.source, Some(disk.clone())).unwrap();
    let r3 = run(&app, &o2, p3);
    let s3 = r3.program.cache_stats();
    assert_eq!(s3.misses, 0, "warm O2 entries are reused");
    assert!(s3.disk_hits > 0);

    // Both levels agree bit-for-bit on the results.
    assert_bit_identical(&r1.buffers, &r2.buffers, "O2 vs O0");
    runner::verify(&app, &r1.buffers).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
