//! Property tests for Bufalloc: random alloc/free interleavings keep the
//! chunk-list invariants (tiling, ordering, coalescing) intact.

use poclrs::bufalloc::Bufalloc;
use poclrs::testing::{check, Rng};

fn random_workout(rng: &mut Rng, greedy: bool) {
    let region = 1 << 16;
    let mut b = Bufalloc::new(region, 64, greedy);
    let mut live: Vec<(usize, usize)> = Vec::new();
    for _ in 0..200 {
        if rng.bool() || live.is_empty() {
            let size = rng.range(1, 4096);
            match b.alloc(size) {
                Ok(off) => {
                    // No overlap with any live allocation.
                    for &(o, s) in &live {
                        assert!(off + size <= o || o + s <= off, "overlap at {off}");
                    }
                    live.push((off, size));
                }
                Err(_) => {
                    // OOM acceptable only when pressure is real.
                    assert!(b.largest_free() < size + 64);
                }
            }
        } else {
            let idx = rng.below(live.len());
            let (off, _) = live.swap_remove(idx);
            b.free(off).unwrap();
        }
        b.check_invariants().unwrap();
    }
    for (off, _) in live {
        b.free(off).unwrap();
    }
    b.check_invariants().unwrap();
    assert_eq!(b.allocated(), 0);
    assert_eq!(b.chunk_count(), 1, "all memory coalesced back");
}

#[test]
fn prop_bufalloc_first_fit() {
    check(25, |rng| random_workout(rng, false));
}

#[test]
fn prop_bufalloc_greedy() {
    check(25, |rng| random_workout(rng, true));
}
