//! Optimizer correctness and acceptance tests (kcc/opt/):
//!
//! * bit-identical suite results across O0/O1/O2 on the serial, per-lane
//!   gang, and lane-batched vector-gang engines;
//! * a property pass: every optimizer pass, run alone on every suite
//!   kernel's frontend IR, leaves `ir::verify` clean and preserves the
//!   reachable barrier count (and so does the full pipeline at every
//!   level);
//! * the dispatch acceptance criteria: O2 strictly reduces interpreter
//!   dispatches on MatrixMultiplication and BlackScholes, and cuts them
//!   by ≥20% on at least half of the suite apps.

use std::sync::Arc;

use poclrs::cl::{Program, QueueProperties};
use poclrs::devices::{basic::BasicDevice, Device, EngineKind};
use poclrs::ir::cfg::reachable;
use poclrs::ir::func::Function;
use poclrs::ir::inst::Inst;
use poclrs::ir::verify::verify;
use poclrs::kcc::opt::{self, OptLevel};
use poclrs::suite::{all_apps, runner, App, BufInit, SizeClass};

/// Barriers in reachable blocks (unreachable ones may legitimately be
/// dropped by `cfg_simplify`).
fn reachable_barriers(f: &Function) -> usize {
    reachable(f)
        .into_iter()
        .map(|b| {
            f.block(b).insts.iter().filter(|(_, i)| matches!(i, Inst::Barrier { .. })).count()
        })
        .sum()
}

fn assert_bit_identical(a: &[BufInit], b: &[BufInit], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: buffer count");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        match (x, y) {
            (BufInit::F32(u), BufInit::F32(v)) => {
                assert_eq!(u.len(), v.len(), "{what}: buffer {i} length");
                for (j, (p, q)) in u.iter().zip(v).enumerate() {
                    assert_eq!(
                        p.to_bits(),
                        q.to_bits(),
                        "{what}: buffer {i}[{j}] {p} vs {q} not bit-identical"
                    );
                }
            }
            (BufInit::U32(u), BufInit::U32(v)) => assert_eq!(u, v, "{what}: buffer {i}"),
            _ => panic!("{what}: buffer {i} type mismatch"),
        }
    }
}

/// Run `app` on a basic device pinned to `level`, verify against the
/// native baseline, and return the run result.
fn run_at(app: &App, engine: EngineKind, level: OptLevel) -> runner::RunResult {
    let device: Arc<dyn Device> = Arc::new(BasicDevice::with_opt_level(engine, level));
    let program = Program::build(app.source).unwrap();
    let r = runner::run_with_program(app, device, QueueProperties::InOrder, program)
        .unwrap_or_else(|e| panic!("{} at {level:?} on {engine:?}: {e}", app.name));
    runner::verify(app, &r.buffers)
        .unwrap_or_else(|e| panic!("{} at {level:?} on {engine:?}: {e}", app.name));
    r
}

/// Acceptance criterion: every suite app produces **bit-identical**
/// output buffers at O0, O1, and O2 on all three CPU engine classes.
#[test]
fn suite_results_bit_identical_across_opt_levels() {
    let engines = [EngineKind::Serial, EngineKind::Gang(4), EngineKind::GangVector(4)];
    for app in all_apps(SizeClass::Small) {
        for engine in engines {
            let base = run_at(&app, engine, OptLevel::O0);
            for level in [OptLevel::O1, OptLevel::O2] {
                let got = run_at(&app, engine, level);
                assert_bit_identical(
                    &base.buffers,
                    &got.buffers,
                    &format!("{} on {engine:?}, O0 vs {level:?}", app.name),
                );
            }
        }
    }
}

/// Property: each pass in isolation keeps the IR verifier happy and the
/// reachable barrier count intact, on every kernel of every suite app.
#[test]
fn every_pass_verifies_and_preserves_barriers_on_every_suite_kernel() {
    type Pass = (&'static str, fn(&mut Function) -> usize);
    let passes: [Pass; 7] = [
        ("cfg_simplify", opt::cfg_simplify::run),
        ("fold", opt::fold::run),
        ("algebraic", opt::algebraic::run),
        ("propagate", opt::propagate::run),
        ("cse", opt::cse::run),
        ("loadfwd", opt::loadfwd::run),
        ("dce", opt::dce::run),
    ];
    for app in all_apps(SizeClass::Small) {
        let module = poclrs::frontend::compile(app.source).unwrap();
        for k in &module.kernels {
            verify(k).unwrap_or_else(|e| panic!("{}::{}: frontend IR: {e:?}", app.name, k.name));
            let barriers = reachable_barriers(k);
            for (pname, pass) in passes {
                let mut f = k.clone();
                pass(&mut f);
                verify(&f)
                    .unwrap_or_else(|e| panic!("{}::{} after {pname}: {e:?}", app.name, k.name));
                assert_eq!(
                    reachable_barriers(&f),
                    barriers,
                    "{}::{}: {pname} changed the barrier count",
                    app.name,
                    k.name
                );
            }
            // The full pipeline at every level preserves barriers too
            // (and re-verifies internally).
            for level in [OptLevel::O0, OptLevel::O1, OptLevel::O2] {
                let mut f = k.clone();
                let stats = opt::run(&mut f, level)
                    .unwrap_or_else(|e| panic!("{}::{} at {level:?}: {e:?}", app.name, k.name));
                assert_eq!(
                    reachable_barriers(&f),
                    barriers,
                    "{}::{}: pipeline at {level:?} changed the barrier count",
                    app.name,
                    k.name
                );
                assert!(
                    stats.insts_after <= stats.insts_before,
                    "{}::{} at {level:?}: the optimizer never grows the function",
                    app.name,
                    k.name
                );
            }
        }
    }
}

/// Total interpreter dispatches for one full app run on the per-lane
/// gang engine pinned to `level`.
fn dispatches_at(app: &App, level: OptLevel) -> usize {
    run_at(app, EngineKind::Gang(4), level).stats.dispatches()
}

/// Acceptance criteria: O2 strictly reduces dispatch counts on
/// MatrixMultiplication and BlackScholes, and achieves ≥20% reduction on
/// at least half of the suite apps.
#[test]
fn o2_cuts_interpreter_dispatches() {
    let mut total = 0usize;
    let mut reduced20 = 0usize;
    let mut anchors_seen = 0usize;
    let mut lines = Vec::new();
    for app in all_apps(SizeClass::Small) {
        let d0 = dispatches_at(&app, OptLevel::O0);
        let d2 = dispatches_at(&app, OptLevel::O2);
        total += 1;
        if d2 * 5 <= d0 * 4 {
            reduced20 += 1;
        }
        lines.push(format!("{:<22} O0={d0:>9} O2={d2:>9}", app.name));
        if app.name == "MatrixMultiplication" || app.name == "BlackScholes" {
            anchors_seen += 1;
            assert!(
                d2 < d0,
                "{}: O2 must strictly reduce dispatches (O0={d0}, O2={d2})",
                app.name
            );
        }
    }
    assert_eq!(anchors_seen, 2, "both anchor apps must be in the suite");
    assert!(
        reduced20 * 2 >= total,
        "O2 must cut dispatches by >=20% on at least half the suite ({reduced20}/{total}):\n{}",
        lines.join("\n")
    );
}
