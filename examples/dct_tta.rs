//! The §6.4 experiment: DCT on the TTA simulator with and without
//! horizontal inner-loop parallelisation (Table 2 datapath).
//!
//! The paper reports 53.5 ms → 10.2 ms at 100 MHz (≈5× ILP gain). The
//! simulated ratio here reproduces the *shape*: the kernel compiler's
//! parallel-loop metadata lets the static scheduler overlap work-item
//! iterations and fill the FUs.

use std::sync::Arc;

use poclrs::devices::ttasim::TtaSimDevice;
use poclrs::devices::Device;
use poclrs::suite::{apps::dct, runner, SizeClass};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let app = dct::build(SizeClass::Bench);
    let mut cycles = Vec::new();
    for horizontal in [false, true] {
        let device = Arc::new(TtaSimDevice::new(horizontal));
        let r = runner::run_and_verify(&app, device.clone() as Arc<dyn Device>)?;
        let ms = device.cycles_to_ms(r.stats.cycles);
        println!(
            "DCT on ttasim (horizontal={horizontal:5}): {:>12} cycles  =  {:8.2} ms @100MHz",
            r.stats.cycles, ms
        );
        cycles.push(r.stats.cycles);
    }
    let speedup = cycles[0] as f64 / cycles[1] as f64;
    println!("ILP speedup from horizontal inner-loop parallelisation: {speedup:.2}x");
    println!("(paper §6.4: 53.5 ms → 10.2 ms ≈ 5.2x)");
    Ok(())
}
