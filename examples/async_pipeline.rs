//! Overlapped transfer/compute with the out-of-order queue.
//!
//! A chunked pipeline: every chunk is an independent
//! `write → kernel → read` chain whose edges are declared through event
//! wait-lists. On an out-of-order queue the chains run concurrently on
//! the worker pool — chunk 2's upload overlaps chunk 1's compute — while
//! each chain stays internally ordered. The event timeline printed at
//! the end makes the overlap visible.
//!
//! ```sh
//! cargo run --release --example async_pipeline
//! ```

use std::sync::Arc;

use poclrs::cl::{CommandQueue, Context, Kernel, KernelArg, Platform, Program, QueueProperties};

const SRC: &str = r#"
__kernel void smooth(__global float *x, int iters) {
    size_t g = get_global_id(0);
    float v = x[g];
    for (int i = 0; i < iters; i++) { v = v * 0.999f + 0.001f; }
    x[g] = v;
}
"#;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    const CHUNKS: usize = 4;
    const N: usize = 4096;
    const ITERS: i32 = 400;

    let platform = Platform::default_platform();
    let ctx = Arc::new(Context::new(platform.find_device("basic-serial")?));
    let queue = CommandQueue::with_properties(ctx.clone(), QueueProperties::OutOfOrder);
    let program = Program::build(SRC)?;

    let host: Vec<Vec<f32>> =
        (0..CHUNKS).map(|c| vec![1.0 + c as f32; N]).collect();
    let mut reads = Vec::new();
    for chunk in 0..CHUNKS {
        let buf = ctx.create_buffer(N * 4)?;
        // Independent chain: write → kernel → read, edges via wait-lists.
        let w = queue.enqueue_write_slice(buf, &host[chunk], &[])?;
        let mut k = Kernel::new(&program, "smooth")?;
        k.set_arg(0, KernelArg::Buf(buf))?;
        k.set_arg(1, KernelArg::I32(ITERS))?;
        let c = queue.enqueue_nd_range(&program, &k, [N, 1, 1], [64, 1, 1], &[w])?;
        reads.push(queue.enqueue_read_buffer(buf, 0, N * 4, &[c])?);
    }
    // Nothing has run yet — commands are deferred until the flush.
    queue.flush();

    for (chunk, rd) in reads.iter().enumerate() {
        let out: Vec<f32> = rd.wait_vec()?;
        assert!(out.iter().all(|&v| v > 0.99 && v < 1.0 + CHUNKS as f32));
        println!("chunk {chunk}: {} elements processed, x[0] = {:.4}", out.len(), out[0]);
    }
    queue.finish()?;

    println!("\nevent timeline (ns since queue creation):");
    println!("{:<14} {:>12} {:>12} {:>12} {:>12}", "command", "queued", "submitted", "start", "end");
    for ev in queue.events() {
        let p = ev.profile();
        println!(
            "{:<14} {:>12} {:>12} {:>12} {:>12}",
            ev.what(),
            p.queued_ns,
            p.submitted_ns,
            p.start_ns,
            p.end_ns
        );
    }
    println!("\npeak concurrent commands on the worker pool: {}", queue.max_concurrency());
    Ok(())
}
