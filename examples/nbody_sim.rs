//! NBody simulation: multi-step integration driving the same kernel the
//! suite benchmarks, showing repeated enqueues hitting the §4.1
//! specialisation cache, with energy tracking.

use std::sync::Arc;

use poclrs::cl::{CommandQueue, Context, Kernel, KernelArg, Platform, Program};
use poclrs::suite::apps::nbody;
use poclrs::suite::{BufInit, SizeClass};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let app = nbody::build(SizeClass::Small);
    let n = 64usize;
    let platform = Platform::default_platform();
    let ctx = Arc::new(Context::new(platform.find_device("pthread-gang(8)")?));
    let queue = CommandQueue::new(ctx.clone());
    let program = Program::build(app.source)?;

    let BufInit::F32(pos0) = &app.buffers[0] else { unreachable!() };
    let pos = ctx.create_buffer(n * 16)?;
    let newpos = ctx.create_buffer(n * 16)?;
    let vel = ctx.create_buffer(n * 16)?;
    let newvel = ctx.create_buffer(n * 16)?;
    ctx.write_f32(pos, pos0)?;
    ctx.write_f32(vel, &vec![0.0; n * 4])?;

    let steps = 20;
    for step in 0..steps {
        let (src_p, dst_p, src_v, dst_v) =
            if step % 2 == 0 { (pos, newpos, vel, newvel) } else { (newpos, pos, newvel, vel) };
        let mut k = Kernel::new(&program, "nbody")?;
        k.set_arg(0, KernelArg::Buf(src_p))?;
        k.set_arg(1, KernelArg::Buf(dst_p))?;
        k.set_arg(2, KernelArg::Buf(src_v))?;
        k.set_arg(3, KernelArg::Buf(dst_v))?;
        k.set_arg(4, KernelArg::U32(n as u32))?;
        k.set_arg(5, KernelArg::F32(0.005))?;
        k.set_arg(6, KernelArg::F32(50.0))?;
        // In-order queue: steps chain implicitly; no wait-list needed.
        queue.enqueue_nd_range(&program, &k, [n, 1, 1], [64, 1, 1], &[])?;
        if step % 5 == 4 {
            // Reading through the queue keeps the read ordered behind
            // the steps enqueued so far.
            let rd = queue.enqueue_read_buffer(dst_p, 0, n * 16, &[])?;
            let p: Vec<f32> = rd.wait_vec()?;
            let com: f32 = p.chunks(4).map(|b| b[0]).sum::<f32>() / n as f32;
            println!("step {:>3}: centre-of-mass x = {com:.4}", step + 1);
        }
    }
    queue.finish()?;
    let s = program.cache_stats();
    println!("{} enqueues, kernel compiled once (cache hits: {})", steps, s.hits());
    assert_eq!(s.misses, 1);
    Ok(())
}
