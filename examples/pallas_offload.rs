//! **End-to-end driver**: proves the three layers compose.
//!
//! Three suite workloads (MatrixMultiplication, BlackScholes, NBody) run
//! through the full stack on the `pjrt` SPMD device: the kernels were
//! authored as **Pallas (L1)** kernels inside **JAX (L2)** programs,
//! AOT-lowered by `make artifacts` to HLO text, and are loaded + executed
//! here from the **Rust (L3)** host layer through the PJRT C API — Python
//! never runs in this binary. Results are verified against the native
//! baselines and cross-checked against the host gang engine; latency and
//! throughput are reported.
//!
//! ```sh
//! make artifacts && cargo run --release --example pallas_offload
//! ```

use std::sync::Arc;
use std::time::Instant;

use poclrs::devices::pjrt::{KernelBinding, PjrtDevice};
use poclrs::devices::{basic::BasicDevice, Device, EngineKind};
use poclrs::runtime::ArgSpec;
use poclrs::suite::{app_by_name, runner, SizeClass};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let art = |name: &str| format!("artifacts/{name}.hlo.txt");
    for name in ["matmul", "blackscholes", "nbody"] {
        if !std::path::Path::new(&art(name)).exists() {
            eprintln!("missing {} — run `make artifacts` first", art(name));
            std::process::exit(1);
        }
    }

    let mut pjrt = PjrtDevice::new()?;
    // MatrixMultiplication: kernel args [C, A, B, n, locals...] → XLA
    // inputs (A, B), output C.
    let n = 64usize;
    pjrt.register(
        "matmul",
        KernelBinding {
            artifact: art("matmul"),
            inputs: vec![(1, ArgSpec::f32(&[n * n])), (2, ArgSpec::f32(&[n * n]))],
            outputs: vec![(0, n * n)],
        },
    );
    // BlackScholes: args [rnd, call, put] → inputs (rnd), outputs (call, put).
    let bsn = 1usize << 14;
    pjrt.register(
        "blackscholes",
        KernelBinding {
            artifact: art("blackscholes"),
            inputs: vec![(0, ArgSpec::f32(&[bsn]))],
            outputs: vec![(1, bsn), (2, bsn)],
        },
    );
    // NBody: args [pos, newPos, vel, newVel, ...] → inputs (pos, vel),
    // outputs (newPos, newVel).
    let bodies = 512usize;
    pjrt.register(
        "nbody",
        KernelBinding {
            artifact: art("nbody"),
            inputs: vec![(0, ArgSpec::f32(&[bodies * 4])), (2, ArgSpec::f32(&[bodies * 4]))],
            outputs: vec![(1, bodies * 4), (3, bodies * 4)],
        },
    );
    for k in ["matmul", "blackscholes", "nbody"] {
        pjrt.warm(k)?; // compile once, amortised across launches
    }
    let pjrt: Arc<dyn Device> = Arc::new(pjrt);
    let gang: Arc<dyn Device> = Arc::new(BasicDevice::new(EngineKind::Gang(8)));

    println!("{:<22} {:>12} {:>14} {:>16}", "workload", "pjrt (ms)", "host-gang (ms)", "items/s (pjrt)");
    for (app_name, items) in [
        ("MatrixMultiplication", (n * n) as f64),
        ("BlackScholes", bsn as f64),
        ("NBody", bodies as f64),
    ] {
        let app = app_by_name(app_name, SizeClass::Bench).unwrap();
        // Full-stack run on the pjrt device (+ verification vs native).
        let t0 = Instant::now();
        let r = runner::run_and_verify(&app, pjrt.clone())?;
        let pjrt_ms = t0.elapsed().as_secs_f64() * 1e3;
        let _ = r;
        // Cross-check: the host gang engine must agree too.
        let t1 = Instant::now();
        runner::run_and_verify(&app, gang.clone())?;
        let gang_ms = t1.elapsed().as_secs_f64() * 1e3;
        println!(
            "{:<22} {:>12.3} {:>14.3} {:>16.0}",
            app_name,
            pjrt_ms,
            gang_ms,
            items / (pjrt_ms / 1e3)
        );
    }
    println!("\nall layers verified: Pallas(L1) → JAX(L2) → HLO artifact → rust PJRT (L3)");
    Ok(())
}
