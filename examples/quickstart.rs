//! Quickstart: vector addition through the full host API (Fig. 1's dot
//! product sibling) on the threaded gang device.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use poclrs::cl::{CommandQueue, Context, Kernel, KernelArg, Platform, Program};

const SRC: &str = r#"
__kernel void vecadd(__global const float *a, __global const float *b, __global float *c) {
    size_t i = get_global_id(0);
    c[i] = a[i] + b[i];
}
"#;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. Platform + device discovery (Table 1).
    let platform = Platform::default_platform();
    println!("platform `{}`:\n{}", platform.name, platform.capability_table());
    let device = platform.find_device("pthread-gang(8)")?;

    // 2. Context, program, buffers.
    let ctx = Arc::new(Context::new(device));
    let program = Program::build(SRC)?;
    let n = 1 << 16;
    let a = ctx.create_buffer(n * 4)?;
    let b = ctx.create_buffer(n * 4)?;
    let c = ctx.create_buffer(n * 4)?;
    let av: Vec<f32> = (0..n).map(|i| i as f32).collect();
    let bv: Vec<f32> = (0..n).map(|i| (2 * i) as f32).collect();

    // 3. Kernel + deferred enqueues on an (in-order) queue: the writes,
    //    the launch, and the read are all commands with live events.
    let mut kernel = Kernel::new(&program, "vecadd")?;
    kernel.set_arg(0, KernelArg::Buf(a))?;
    kernel.set_arg(1, KernelArg::Buf(b))?;
    kernel.set_arg(2, KernelArg::Buf(c))?;
    let queue = CommandQueue::new(ctx.clone());
    let wa = queue.enqueue_write_slice(a, &av, &[])?;
    let wb = queue.enqueue_write_slice(b, &bv, &[])?;
    let ev = queue.enqueue_nd_range(&program, &kernel, [n, 1, 1], [64, 1, 1], &[wa, wb])?;
    let rd = queue.enqueue_read_buffer(c, 0, n * 4, &[ev.clone()])?;
    queue.flush();

    // 4. Wait on the events and verify.
    let out: Vec<f32> = rd.wait_vec()?;
    let stats = ev.wait()?;
    println!(
        "vecadd: {} work-groups in {:.3} ms",
        stats.workgroups,
        ev.duration_ns() as f64 / 1e6
    );
    assert!(out.iter().enumerate().all(|(i, &v)| v == 3.0 * i as f32));
    println!("OK: c[i] == 3*i for all {n} elements");
    queue.finish()?;
    Ok(())
}
