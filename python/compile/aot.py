"""AOT pipeline: lower each L2 entry to HLO **text** artifacts the rust
runtime loads with `HloModuleProto::from_text_file`.

HLO text (not `.serialize()`): jax >= 0.5 emits protos with 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids. See /opt/xla-example/README.md.
"""

import argparse
import pathlib

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    for name, (fn, _shapes) in model.ENTRIES.items():
        lowered = jax.jit(fn).lower(*model.example_args(name))
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
