"""L1 Pallas kernel: tiled matmul.

TPU notes (DESIGN.md §Hardware-Adaptation): the BlockSpec tiles map HBM->
VMEM transfers; tiles are MXU-shaped (multiples of 8x128 would be used at
real sizes -- the suite's 64x64 problem fits one VMEM tile outright, so a
single-block kernel is the roofline-optimal schedule). interpret=True is
mandatory on CPU (Mosaic custom-calls cannot run on the CPU plugin).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def matmul(a, b):
    """Single-tile Pallas matmul (shapes must fit VMEM; fine <= 256x256)."""
    n, k = a.shape
    k2, m = b.shape
    assert k == k2
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(a, b)


def matmul_tiled(a, b, tile=32):
    """Grid-tiled variant: (i, j) output tiles, full-K panels staged in
    VMEM -- the schedule a real TPU deployment would use for larger n."""
    n, k = a.shape
    _, m = b.shape
    assert n % tile == 0 and m % tile == 0

    def kern(a_ref, b_ref, o_ref):
        o_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    return pl.pallas_call(
        kern,
        grid=(n // tile, m // tile),
        in_specs=[
            pl.BlockSpec((tile, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tile), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(a, b)
