"""Pure-jnp oracles for the Pallas kernels (the correctness reference the
pytest suite asserts against)."""

import jax.numpy as jnp

# Matches rust/src/suite/apps/blackscholes.rs (Abramowitz-Stegun CND).
_C = (0.319381530, -0.356563782, 1.781477937, -1.821255978, 1.330274429)


def phi(x):
    zabs = jnp.abs(x)
    k2 = 1.0 / (1.0 + 0.2316419 * zabs)
    poly = k2 * (_C[0] + k2 * (_C[1] + k2 * (_C[2] + k2 * (_C[3] + k2 * _C[4]))))
    pdf = 0.3989422804 * jnp.exp(-0.5 * zabs * zabs)
    cnd = 1.0 - pdf * poly
    return jnp.where(x < 0.0, 1.0 - cnd, cnd)


def blackscholes(rnd):
    """Call/put prices from uniform randoms, same parameterisation as the
    MiniCL suite kernel."""
    s = 10.0 + rnd * 90.0
    k = 10.0 + rnd * 90.0
    t = 1.0 + rnd * 9.0
    r = 0.01
    sigma = 0.10 + rnd * 0.4
    sqrt_t = jnp.sqrt(t)
    d1 = (jnp.log(s / k) + (r + sigma * sigma * 0.5) * t) / (sigma * sqrt_t)
    d2 = d1 - sigma * sqrt_t
    kexp = k * jnp.exp(-r * t)
    call = s * phi(d1) - kexp * phi(d2)
    put = kexp * phi(-d2) - s * phi(-d1)
    return call, put


def matmul(a, b):
    """Plain f32 GEMM."""
    return jnp.matmul(a, b)


def nbody(pos, vel, dt=0.005, eps=50.0):
    """All-pairs gravity step over (n,4) [x,y,z,mass] positions."""
    p = pos[:, :3]
    m = pos[:, 3]
    r = p[None, :, :] - p[:, None, :]          # (n, n, 3)
    dist_sqr = jnp.sum(r * r, axis=-1) + eps    # (n, n)
    inv = 1.0 / jnp.sqrt(dist_sqr)
    s = m[None, :] * inv * inv * inv            # (n, n)
    acc = jnp.sum(s[:, :, None] * r, axis=1)    # (n, 3)
    new_p3 = p + vel[:, :3] * dt + acc * (0.5 * dt * dt)
    new_v3 = vel[:, :3] + acc * dt
    new_pos = jnp.concatenate([new_p3, pos[:, 3:4]], axis=1)
    new_vel = jnp.concatenate([new_v3, vel[:, 3:4]], axis=1)
    return new_pos, new_vel
