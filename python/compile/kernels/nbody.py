"""L1 Pallas kernel: all-pairs NBody step.

The O(n^2) interaction is tiled over target bodies: each grid step loads a
block of "my" bodies plus the full source set (n=512 -> 8 KiB, trivially
VMEM-resident; at larger n the source panel would be double-buffered)."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(pos_blk_ref, vel_blk_ref, pos_all_ref, np_ref, nv_ref, *, dt, eps):
    my = pos_blk_ref[...]
    vel = vel_blk_ref[...]
    allp = pos_all_ref[...]
    p = my[:, :3]
    r = allp[None, :, :3] - p[:, None, :]
    dist_sqr = jnp.sum(r * r, axis=-1) + eps
    inv = 1.0 / jnp.sqrt(dist_sqr)
    s = allp[None, :, 3] * inv * inv * inv
    acc = jnp.sum(s[:, :, None] * r, axis=1)
    np_ref[...] = jnp.concatenate(
        [p + vel[:, :3] * dt + acc * (0.5 * dt * dt), my[:, 3:4]], axis=1
    )
    nv_ref[...] = jnp.concatenate([vel[:, :3] + acc * dt, vel[:, 3:4]], axis=1)


def nbody(pos, vel, dt=0.005, eps=50.0, block=128):
    """One integration step; returns (new_pos, new_vel), both (n,4)."""
    import functools

    n = pos.shape[0]
    if n % block != 0:
        block = n
    kern = functools.partial(_kernel, dt=dt, eps=eps)
    return pl.pallas_call(
        kern,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block, 4), lambda i: (i, 0)),
            pl.BlockSpec((block, 4), lambda i: (i, 0)),
            pl.BlockSpec((n, 4), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block, 4), lambda i: (i, 0)),
            pl.BlockSpec((block, 4), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 4), jnp.float32),
            jax.ShapeDtypeStruct((n, 4), jnp.float32),
        ],
        interpret=True,
    )(pos, vel, pos)
