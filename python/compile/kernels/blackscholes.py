"""L1 Pallas kernel: BlackScholes option pricing (elementwise, blocked
1-D grid so each block's working set stays in VMEM)."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _kernel(rnd_ref, call_ref, put_ref):
    rnd = rnd_ref[...]
    call, put = ref.blackscholes(rnd)
    call_ref[...] = call
    put_ref[...] = put


def blackscholes(rnd, block=2048):
    """Blocked elementwise pricing; `block` sized well under VMEM."""
    n = rnd.shape[0]
    if n % block != 0:
        block = n
    return pl.pallas_call(
        _kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)), pl.BlockSpec((block,), lambda i: (i,))],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(rnd)
