"""L2: jax entry points the AOT pipeline lowers -- each wraps an L1 Pallas
kernel with the exact shapes the rust pjrt device launches (the suite's
Bench sizes)."""

import jax.numpy as jnp

from .kernels import blackscholes as bs
from .kernels import matmul as mm
from .kernels import nbody as nb

# Shapes must stay in sync with rust/src/suite (SizeClass::Bench) and the
# bindings in examples/pallas_offload.rs.
MATMUL_N = 64
BLACKSCHOLES_N = 1 << 14
NBODY_N = 512


def matmul_entry(a_flat, b_flat):
    """C = A @ B over flat row-major f32 buffers (the device-buffer view)."""
    a = a_flat.reshape(MATMUL_N, MATMUL_N)
    b = b_flat.reshape(MATMUL_N, MATMUL_N)
    return (mm.matmul(a, b).reshape(-1),)


def blackscholes_entry(rnd):
    call, put = bs.blackscholes(rnd)
    return (call, put)


def nbody_entry(pos_flat, vel_flat):
    pos = pos_flat.reshape(NBODY_N, 4)
    vel = vel_flat.reshape(NBODY_N, 4)
    new_pos, new_vel = nb.nbody(pos, vel)
    return (new_pos.reshape(-1), new_vel.reshape(-1))


ENTRIES = {
    "matmul": (
        matmul_entry,
        [(MATMUL_N * MATMUL_N,), (MATMUL_N * MATMUL_N,)],
    ),
    "blackscholes": (blackscholes_entry, [(BLACKSCHOLES_N,)]),
    "nbody": (nbody_entry, [(NBODY_N * 4,), (NBODY_N * 4,)]),
}


def example_args(name):
    _, shapes = ENTRIES[name]
    import jax

    return [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
