"""L2 model entries: shapes, lowering, and AOT HLO-text generation."""

import jax
import numpy as np

from compile import aot, model


def test_entries_shapes():
    for name, (fn, shapes) in model.ENTRIES.items():
        args = [np.zeros(s, dtype=np.float32) for s in shapes]
        out = fn(*args)
        assert isinstance(out, tuple), name
        for o in out:
            assert o.dtype == np.float32


def test_matmul_entry_matches_dense():
    r = np.random.default_rng(1)
    a = r.standard_normal(model.MATMUL_N * model.MATMUL_N).astype(np.float32)
    b = r.standard_normal(model.MATMUL_N * model.MATMUL_N).astype(np.float32)
    (c,) = model.matmul_entry(a, b)
    want = (
        a.reshape(model.MATMUL_N, -1) @ b.reshape(model.MATMUL_N, -1)
    ).reshape(-1)
    np.testing.assert_allclose(np.asarray(c), want, rtol=1e-4, atol=1e-4)


def test_hlo_text_generation(tmp_path):
    lowered = jax.jit(model.matmul_entry).lower(*model.example_args("matmul"))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert len(text) > 100
