"""Pallas kernels vs pure-jnp oracles -- the core L1 correctness signal.

Hypothesis sweeps shapes/values; assert_allclose against ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import blackscholes as bs
from compile.kernels import matmul as mm
from compile.kernels import nbody as nb
from compile.kernels import ref


def rng(seed):
    return np.random.default_rng(seed)


@settings(max_examples=10, deadline=None)
@given(
    n=st.sampled_from([8, 16, 32, 64]),
    k=st.sampled_from([8, 16, 64]),
    m=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(n, k, m, seed):
    r = rng(seed)
    a = r.standard_normal((n, k), dtype=np.float32)
    b = r.standard_normal((k, m), dtype=np.float32)
    got = np.asarray(mm.matmul(a, b))
    want = np.asarray(ref.matmul(a, b))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(
    n=st.sampled_from([32, 64, 128]),
    tile=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_tiled_matches_ref(n, tile, seed):
    if n % tile != 0:
        pytest.skip("tile must divide n")
    r = rng(seed)
    a = r.standard_normal((n, n), dtype=np.float32)
    b = r.standard_normal((n, n), dtype=np.float32)
    got = np.asarray(mm.matmul_tiled(a, b, tile=tile))
    # n=128 accumulations: XLA may reassociate the K-reduction, so the
    # tolerance is one decade looser than the single-tile case.
    np.testing.assert_allclose(got, np.asarray(ref.matmul(a, b)), rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(n=st.sampled_from([256, 1024, 2048]), seed=st.integers(0, 2**31 - 1))
def test_blackscholes_matches_ref(n, seed):
    r = rng(seed)
    rnd = r.random(n, dtype=np.float32)
    call, put = bs.blackscholes(rnd)
    rc, rp = ref.blackscholes(rnd)
    np.testing.assert_allclose(np.asarray(call), np.asarray(rc), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(put), np.asarray(rp), rtol=1e-5, atol=1e-6)


@settings(max_examples=5, deadline=None)
@given(n=st.sampled_from([64, 128, 256]), seed=st.integers(0, 2**31 - 1))
def test_nbody_matches_ref(n, seed):
    r = rng(seed)
    pos = r.random((n, 4), dtype=np.float32)
    vel = np.zeros((n, 4), dtype=np.float32)
    np_got, nv_got = nb.nbody(pos, vel)
    np_want, nv_want = ref.nbody(pos, vel)
    np.testing.assert_allclose(np.asarray(np_got), np.asarray(np_want), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(nv_got), np.asarray(nv_want), rtol=1e-4, atol=1e-5)


def test_nbody_conserves_mass_column():
    r = rng(0)
    pos = r.random((128, 4), dtype=np.float32)
    vel = r.random((128, 4), dtype=np.float32)
    np_got, nv_got = nb.nbody(pos, vel)
    np.testing.assert_array_equal(np.asarray(np_got)[:, 3], pos[:, 3])
    np.testing.assert_array_equal(np.asarray(nv_got)[:, 3], vel[:, 3])
